"""Jitted epoch-batched event engine: the compiled virtual clock.

`repro.core.events.run_events` drives its virtual clock from Python: every
arrival/completion/deadline event pays a host round-trip even though the
replan (PR 4) and the planner's slot state already live on the device.
This module compiles the clock itself: **all events inside a time epoch
run in one jitted step** — a `lax.while_loop` whose body replicates the
host loop's per-timestamp contract exactly (completions, deadline sheds,
arrivals, queue rejections, then the preempt/admit/replan/dispatch cycle)
over fixed-capacity device arrays.  The host merely feeds epoch
boundaries and drains O(1) scalars per epoch, so a million-request trace
replays in constant host memory (`repro.core.streaming` accumulators are
folded inside the traced step).

Architecture (see docs/EVENT_ENGINE.md for the full design):

- **epoch segmentation**: arrivals are sorted once; the host advances a
  cursor ``chunk`` arrivals at a time and calls the jitted ``step(state,
  consts, t_hi)`` with ``t_hi`` = the last arrival time of the chunk (the
  final epoch uses +inf).  ``t_hi`` is a *traced* operand, so varying
  epoch widths never retrace — one compilation per static configuration,
  cached module-wide in `_ENGINE_CACHE`.
- **traced state**: every mutable quantity of the host loop is a device
  array in one state pytree — slot columns, the `FleetEngineSim` calendar
  columns (drained via `repro.serving.loadsim.traced_advance`), per-class
  FIFO rings over a precomputed arrival-order table, a fixed-capacity
  paused buffer for preempted work, per-request outputs, and the
  streaming accumulators.  The admission queue is not a heap: within a
  class, priority order IS arrival order, so a (head, tail) ring per
  class plus an unrolled K-way merge by (class weight, arrival seq)
  reproduces the host heap's pop order exactly.
- **bit-compatibility**: the engine runs under a scoped
  ``jax.experimental.enable_x64`` so all clock/work arithmetic is float64
  with the same op order as the host's numpy (the planner kernel stays
  explicitly float32 on both paths).  The differential oracle
  (`tests/test_oracle_differential.py`, ``engine="compiled"`` lane) pins
  outcome/cost/completion-time equality over the deterministic sweep.

Restrictions vs the host loop (all raise `NotImplementedError`): stage
executors must be *pure functions of (request value, depth, model)* — the
engine tabulates them once up front — and only the stock admission
policies, `FleetLoadModel` load coupling, and ``load_probe=None`` are
supported.  Custom duck-typed policies/sims/probes keep using the host
loop.  ``replan_overhead_s`` and `EventStats.replan_s` are host-loop
wall-clock concepts and are reported as zero/empty here.  The online
estimator ``refresh`` loop also stays host-side (posterior updates need
per-completion service observations) — precomputed
``annotation_schedule`` swaps and the ``explore`` lane ARE supported and
bit-compatible with the host loop.

Fault injection (`repro.core.faults.FaultSchedule`, ISSUE 9) is
supported for engine outages and seeded/forced stage failures with
checkpointed recovery: fault transition times, the per-(request, depth,
attempt) failure draws and the backoff table are traced operands, the
availability mask is an epoch state column, and the planner's
``blocked_depth`` node column is recomputed in-trace — outage/recovery
flips compile ZERO new programs.  The host-only corners raise
`NotImplementedError`: ``timeout_k`` (the forecast-armed cancellation is
a host-side scheduler concept here), ``recovery="restart"`` (the naive
baseline lane of `benchmarks/chaos.py`), and faults combined with
predictive/cost-aware admission (their displaced-work forecast inflation
and the downgrade lane's host-side min-cost search cannot see the
availability mask).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import numpy as np

from repro.core.admission import (
    FAILED,
    REJECTED,
    SERVED,
    SHED,
    TracedAdmission,
    _subtree_reductions,
    get_policy,
    traced_admission,
)
from repro.core.controller import Objective
from repro.core.controller_jax import (
    TrieDevice,
    _resolve_variant,
    objective_scalars,
    traced_fleet_plan,
    trie_engines,
)
from repro.core.events import _DEFAULT_CAPACITY, EventStats, _explore_tables
from repro.core.runtime import ExecutionResult, StageExecutor
from repro.core.streaming import QuantileSketch, welford_merge
from repro.core.trie import Trie, TrieAnnotations

# outcome codes inside the traced state (host strings on the way out)
_OC_SERVED, _OC_REJECTED, _OC_SHED, _OC_FAILED = 0, 1, 2, 3
_OUTCOMES = {_OC_SERVED: SERVED, _OC_REJECTED: REJECTED, _OC_SHED: SHED,
             _OC_FAILED: FAILED}
_CERT_SLACK = 1e-9   # deadline-shed certainty slack (events.py step 1b/2b)
_DONE_TOL = 1e-9     # FleetEngineSim._DONE_TOL
_SLO_TOL = 1e-9      # run_events' final SLO check tolerance

DEFAULT_EPOCH = 1024  # arrivals per jitted step (throughput knob, not math)


@dataclasses.dataclass(frozen=True)
class _EngineConfig:
    """Static specialization key of one compiled engine program.

    Everything here changes the traced program structure; everything that
    merely changes *values* (arrival times, work tables, deadlines,
    objective scalars) is a traced operand instead, so replaying a new
    trace through the same configuration hits the cache."""

    capacity: int
    n_classes: int
    n_engines: int
    n_models: int
    max_depth: int
    priorities: bool
    preempt: bool
    ps: bool               # processor-sharing calendar (vs unit-rate)
    load_aware: bool
    deadline_sheds: bool
    pol: TracedAdmission
    kind: str
    kind_dg: str           # downgrade-lane objective kind (cost_aware)
    variant: str
    n_bins: int            # streaming histogram bins (incl. under/overflow)
    n_shards: int = 1      # lane-axis mesh extent (1 = single device)
    explore: bool = False  # epsilon-greedy exploration lane (ISSUE 8)
    # token-level calendar (ISSUE 10): job rates come from the continuous-
    # batching decode-step throughput curve + KV cap instead of the PS
    # concurrency knee; implies cfg.ps (remaining work tracked in jrm).
    # The curve parameters themselves are traced operands (cn["tkw"] ...).
    tokens: bool = False
    # fault injection (ISSUE 9): outage transitions and/or stage-failure
    # draws change the traced program; the schedule itself is operands
    fault_outages: bool = False
    fault_failures: bool = False
    max_retries: int = 0   # retry budget (exhaustion compare is traced-free)
    paused_cap: int = 0    # paused-buffer rows per class (C normally; B
    #                        under outages, whose victims can stack past C)


_ENGINE_CACHE: dict[_EngineConfig, Callable] = {}


def compiled_engine_cache_size() -> int:
    """Total compiled specializations across every engine program this
    process traced, or -1 when the JAX runtime doesn't expose the counter
    — the zero-retrace guard the tests pin: epoch width, trace content,
    deadlines, and objective scalars are all traced operands, so replaying
    new traces through a known configuration must not grow this."""
    total = 0
    for fn in _ENGINE_CACHE.values():
        try:
            total += fn._cache_size()
        except Exception:
            return -1
    return total


def _build_step(cfg: _EngineConfig):
    """Trace-and-cache the jitted epoch step for one static config."""
    if cfg in _ENGINE_CACHE:
        return _ENGINE_CACHE[cfg]

    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.dist.sharding import LANE_AXIS
    from repro.serving.loadsim import traced_advance, traced_engine_rates, \
        traced_job_rates, traced_token_rates

    C, K, E, M = cfg.capacity, cfg.n_classes, cfg.n_engines, cfg.n_models
    P = cfg.paused_cap
    # the paused buffer exists for priority preemption AND for outage
    # checkpoints (stage model -1 = replan on admit), so every structural
    # gate on its presence keys off this union, not cfg.priorities alone
    paused_on = cfg.priorities or cfg.fault_outages
    fault_any = cfg.fault_outages or cfg.fault_failures
    pol = cfg.pol
    i32 = jnp.int32

    def scat_set(dst, idx, val, mask):
        """Masked scatter into a (B,)-indexed array (drop when ~mask)."""
        B = dst.shape[0]
        return dst.at[jnp.where(mask, idx, B)].set(val, mode="drop")

    def scat_add(dst, idx, val, mask):
        B = dst.shape[0]
        return dst.at[jnp.where(mask, idx, B)].add(val, mode="drop")

    def wmerge(wt, cnt, mean, m2):
        """Fold a batch (count, mean, M2) into running Welford state —
        Chan's parallel merge, trace-safe (no data-dependent branches)."""
        c0, m0, s0 = wt
        tot = c0 + cnt
        tot_s = jnp.where(tot > 0, tot, 1.0)
        d = mean - m0
        m = m0 + d * cnt / tot_s
        s = s0 + m2 + d * d * c0 * cnt / tot_s
        keep = cnt > 0
        return (jnp.where(keep, tot, c0), jnp.where(keep, m, m0),
                jnp.where(keep, s, s0))

    def batch_stats(x, mask):
        cnt = jnp.sum(jnp.where(mask, 1.0, 0.0))
        mean = jnp.sum(jnp.where(mask, x, 0.0)) / jnp.where(cnt > 0, cnt, 1.0)
        m2 = jnp.sum(jnp.where(mask, (x - mean) ** 2, 0.0))
        return cnt, mean, m2

    def record_terminal(st, cn, req, valid, t, outcome, cost):
        """Every terminal disposition funnels through here: outputs,
        done-counter, and the streaming accumulators (latency/cost moments
        and the quantile histogram over SERVED requests, SLO-violation
        count over all terminal requests)."""
        B = st["roc"].shape[0]
        reqc = jnp.clip(req, 0, B - 1)
        st = dict(st)
        st["roc"] = scat_set(st["roc"], req, outcome, valid)
        st["rdn"] = scat_set(st["rdn"], req, t, valid)
        st["rct"] = scat_set(st["rct"], req, cost, valid)
        st["don"] = st["don"] + jnp.sum(jnp.where(valid, 1, 0))
        lat = t - cn["arr"][reqc]
        served = valid & (outcome == _OC_SERVED)
        st["lw"] = wmerge(st["lw"], *batch_stats(lat, served))
        st["cw"] = wmerge(st["cw"], *batch_stats(cost, served))
        bins = jnp.searchsorted(cn["edges"], lat, side="right")
        st["hist"] = st["hist"].at[jnp.where(
            served, bins, cfg.n_bins)].add(1, mode="drop")
        cap = cn["cap"][reqc]
        st["slo"] = st["slo"] + jnp.sum(jnp.where(
            valid & jnp.isfinite(cap) & (lat > cap + _SLO_TOL), 1, 0))
        return st

    def release(st, mask):
        """Host `release_slot` over a (C,) mask: every per-slot column."""
        out = {**st,
               "so": jnp.where(mask, -1, st["so"]),
               "su": jnp.where(mask, 0, st["su"]),
               "sec": jnp.where(mask, 0.0, st["sec"]),
               "sm": jnp.where(mask, -1, st["sm"]),
               "sdg": jnp.where(mask, False, st["sdg"]),
               "sddl": jnp.where(mask, jnp.inf, st["sddl"]),
               "sfree": st["sfree"] | mask}
        if cfg.fault_failures:
            out["srt"] = jnp.where(mask, jnp.inf, st["srt"])
        return out

    def sim_clear(st, mask):
        """`FleetEngineSim._clear` over a (C,) mask."""
        return {**st,
                "je": jnp.where(mask, -1, st["je"]),
                "jtc": jnp.where(mask, jnp.inf, st["jtc"]),
                "jwk": jnp.where(mask, 0.0, st["jwk"]),
                "jrm": jnp.where(mask, jnp.inf, st["jrm"]),
                "jw": jnp.where(mask, 1.0, st["jw"])}

    def remaining_col(st, t):
        """`FleetEngineSim.remaining(t)`: (C,) unloaded seconds, inf idle.
        The calendar was already advanced to t at the event's start."""
        act = st["je"] >= 0
        rem = jnp.maximum(st["jrm"], 0.0) if cfg.ps \
            else jnp.maximum(st["jtc"] - t, 0.0)
        return jnp.where(act, rem, jnp.inf)

    def job_rates(st, cn):
        act = st["je"] >= 0
        occ = jnp.zeros(E + 1, st["jrm"].dtype).at[
            jnp.where(act, jnp.clip(st["je"], 0, E - 1), E)].add(
            jnp.where(act, 1.0, 0.0))[:E]
        if cfg.tokens:
            rates = traced_token_rates(occ, cn["tkw"], cn["tkv"],
                                       cn["tkf"], cn["tkc"], cn["tk1"])
        else:
            rates = traced_engine_rates(occ, cn["conc"])
        return traced_job_rates(st["je"], st["jw"], act, rates, st["wtd"])

    def next_completion(st, cn):
        """`FleetEngineSim.next_completion` — the per-job quotient form,
        value-equal to the host's per-engine min (division by the shared
        positive rate commutes with min exactly in IEEE)."""
        act = st["je"] >= 0
        if not cfg.ps:
            return jnp.min(jnp.where(act, st["jtc"], jnp.inf))
        jr = job_rates(st, cn)
        q = jnp.where(act, jnp.maximum(st["jrm"], 0.0)
                      / jnp.where(act, jr, 1.0), jnp.inf)
        return jnp.where(act.any(), st["tl"] + jnp.min(q), jnp.inf)

    def peak_update(st, cn):
        act = st["je"] >= 0
        occ = jnp.zeros(E + 1, jnp.int64).at[
            jnp.where(act, jnp.clip(st["je"], 0, E - 1), E)].add(
            jnp.where(act, 1, 0))[:E]
        return {**st, "po": jnp.maximum(st["po"], occ)}

    # ------------------------------------------------------------------
    # admission queue: per-class FIFO rings + paused buffer
    # ------------------------------------------------------------------
    def class_head(st, cn, k):
        """(valid, request, is_paused) head of class ``k`` (python int).

        Invariant: every paused seq in a class precedes every never-
        admitted seq (admission consumed the ring in seq order), so the
        class head is the paused buffer's front when non-empty, else the
        fresh ring's front.  Under predictive admission the fresh front
        is kept non-rejected by the skip-dead fixups."""
        fh = st["qh"][k]
        fresh_valid = fh < st["qt"][k]
        fresh_req = cn["members"][k, jnp.clip(fh, 0, cn["arr"].shape[0] - 1)]
        if paused_on:
            has_p = st["pn"][k] > 0
            return (has_p | fresh_valid,
                    jnp.where(has_p, st["pb"][k, 0], fresh_req), has_p)
        return fresh_valid, fresh_req, jnp.asarray(False)

    def merged_head(st, cn):
        """Queue head across classes: max class weight, then min arrival
        seq — exactly the host heap's (-weight, seq) pop order.  Returns
        (valid, class index, request, head weight)."""
        big = jnp.iinfo(jnp.int64).max
        best_k = jnp.asarray(-1, i32)
        best_w = jnp.asarray(-jnp.inf, st["sec"].dtype)
        best_s = jnp.asarray(big, jnp.int64)
        best_r = jnp.asarray(0, i32)
        for k in range(K):
            valid, req, _ = class_head(st, cn, k)
            s = jnp.where(valid, cn["seq"][req], big)
            w = jnp.where(valid, cn["wcls"][k], -jnp.inf)
            better = valid & ((w > best_w) | ((w == best_w) & (s < best_s)))
            best_k = jnp.where(better, k, best_k)
            best_w = jnp.where(better, w, best_w)
            best_s = jnp.where(better, s, best_s)
            best_r = jnp.where(better, req, best_r)
        return best_k >= 0, best_k, best_r, best_w

    def skip_dead(st, cn):
        """Advance each class's fresh head past predictive-rejected
        entries so `class_head` always exposes a live request."""
        if not pol.wants_forecast:
            return st
        B = cn["arr"].shape[0]
        for k in range(K):
            def cond(s, k=k):
                h = s["qh"][k]
                hr = cn["members"][k, jnp.clip(h, 0, B - 1)]
                return (h < s["qt"][k]) & s["dead"][hr]

            def body(s, k=k):
                return {**s, "qh": s["qh"].at[k].add(1)}

            st = lax.while_loop(cond, body, st)
        return st

    def pop_head(st, cn, k_idx):
        """Remove the merged head (class ``k_idx``, traced): paused front
        when present, else the fresh ring front."""
        onehot = jnp.arange(K) == k_idx
        if paused_on:
            from_p = onehot & (st["pn"] > 0)
            shifted = jnp.concatenate(
                [st["pb"][:, 1:], jnp.full((K, 1), -1, i32)], axis=1)
            st = {**st,
                  "pb": jnp.where(from_p[:, None], shifted, st["pb"]),
                  "pn": st["pn"] - from_p.astype(st["pn"].dtype),
                  "qh": st["qh"] + (onehot & ~from_p).astype(st["qh"].dtype)}
        else:
            st = {**st, "qh": st["qh"] + onehot.astype(st["qh"].dtype)}
        return skip_dead(st, cn)

    def paused_insert(st, cn, i, k_idx):
        """Insert request ``i`` into class ``k_idx``'s paused buffer in
        arrival-seq order (the host re-pushes it onto the heap; within a
        class the heap orders by seq)."""
        B = cn["arr"].shape[0]
        row = st["pb"][k_idx]
        iota = jnp.arange(P)
        seqs = jnp.where(iota < st["pn"][k_idx],
                         cn["seq"][jnp.clip(row, 0, B - 1)],
                         jnp.iinfo(jnp.int64).max)
        pos = jnp.sum(jnp.where(seqs < cn["seq"][i], 1, 0))
        new_row = jnp.where(iota < pos, row,
                            jnp.where(iota == pos, i, jnp.roll(row, 1)))
        return {**st,
                "pb": st["pb"].at[k_idx].set(new_row),
                "pn": st["pn"].at[k_idx].add(1),
                "rpp": st["rpp"].at[i].set(True)}

    def shed_paused_rows(st, cn, t, doom_fn):
        """Shed doomed entries out of every paused row (stable compaction),
        mirroring the host's queue-side paused-deadline sheds."""
        B = cn["arr"].shape[0]
        for k in range(K):
            row = st["pb"][k]
            iota = jnp.arange(P)
            activep = iota < st["pn"][k]
            req = jnp.clip(row, 0, B - 1)
            doomed = activep & doom_fn(req)
            ocp = jnp.full(P, _OC_SHED, i32)
            if fault_any:
                # a fault-touched request dies "failed", not "shed"
                flt = st["rfl"][req]
                ocp = jnp.where(flt, _OC_FAILED, ocp)
                st = record_terminal(st, cn, req, doomed, t, ocp,
                                     st["rpec"][req])
                st["ffc"] = st["ffc"] + jnp.sum(
                    jnp.where(doomed & flt, 1, 0))
                st["shd"] = st["shd"] + jnp.sum(
                    jnp.where(doomed & ~flt, 1, 0))
            else:
                st = record_terminal(st, cn, req, doomed, t, ocp,
                                     st["rpec"][req])
                st["shd"] = st["shd"] + jnp.sum(jnp.where(doomed, 1, 0))
            st["rpp"] = scat_set(st["rpp"], req, False, doomed)
            keep = activep & ~doomed
            tgt = jnp.where(keep, jnp.cumsum(keep) - 1, P)
            new_row = jnp.full((P,), -1, i32).at[tgt].set(row, mode="drop")
            st["pb"] = st["pb"].at[k].set(new_row)
            st["pn"] = st["pn"].at[k].set(
                jnp.sum(keep).astype(st["pn"].dtype))
        return st

    def paused_doom(st, cn, t):
        def doom(req):
            ddl = cn["arr"][req] + cn["cap"][req]
            return jnp.isfinite(ddl) & (
                (t >= ddl) | (t + st["rprm"][req] > ddl + _CERT_SLACK))
        return doom

    def shed_oc(st, ownc):
        """(C,) outcome codes for a shed site: "failed" when any fault
        already touched the slot's owner (host `shed`), "shed" otherwise."""
        oc = jnp.full(C, _OC_SHED, i32)
        if fault_any:
            oc = jnp.where(st["rfl"][ownc], _OC_FAILED, oc)
        return oc

    def count_sheds(st, mask, ownc):
        """Mirror `shed_oc`'s split into the shd/ffc counters."""
        st = dict(st)
        if fault_any:
            flt = st["rfl"][ownc]
            st["ffc"] = st["ffc"] + jnp.sum(jnp.where(mask & flt, 1, 0))
            st["shd"] = st["shd"] + jnp.sum(jnp.where(mask & ~flt, 1, 0))
        else:
            st["shd"] = st["shd"] + jnp.sum(jnp.where(mask, 1, 0))
        return st

    # ------------------------------------------------------------------
    # event phases (the numbers mirror events.py's comments)
    # ------------------------------------------------------------------
    def phase_completions(st, cn, t):
        act = st["je"] >= 0
        done = act & ((st["jrm"] <= _DONE_TOL) if cfg.ps
                      else (st["jtc"] <= t))
        own = st["so"]
        newu = cn["child"][st["su"], jnp.clip(st["sm"], 0, M - 1)]
        st = dict(st)
        st["su"] = jnp.where(done, newu, st["su"])
        st["ru"] = scat_set(st["ru"], own, newu, done)
        st["sm"] = jnp.where(done, -1, st["sm"])
        fin = done & st["sok"]
        deep = done & ~st["sok"] & (cn["depth"][newu] >= cfg.max_depth)
        term = fin | deep
        st["rsc"] = scat_set(st["rsc"], own, True, fin)
        st = record_terminal(st, cn, own, term, t,
                             jnp.full(C, _OC_SERVED, i32), st["sec"])
        st["snd"] = st["snd"] | (done & ~term)
        st = release(st, term)
        return sim_clear(st, done)

    def phase_deadline_sheds(st, cn, t):
        if not cfg.deadline_sheds:
            return st
        B = cn["arr"].shape[0]
        # (i) certainty bound on in-service work: PS rate <= 1, so
        # t + remaining lower-bounds completion
        insvc = (st["so"] >= 0) & (st["sm"] >= 0)
        rem = remaining_col(st, t)
        ownc = jnp.clip(st["so"], 0, B - 1)
        ddl = cn["arr"][ownc] + cn["cap"][ownc]
        doomed = insvc & ((t >= ddl) | (t + rem > ddl + _CERT_SLACK))
        st = record_terminal(st, cn, st["so"], doomed, t,
                             shed_oc(st, ownc), st["sec"])
        st = dict(st)
        st = count_sheds(st, doomed, ownc)
        st = sim_clear(st, doomed)
        st = release(st, doomed)
        # (ii) backstop: the deadline column is a scheduled event (it also
        # catches slots held in a fault-retry backoff, whose stage column
        # is idle but whose deadline keeps ticking)
        mask2 = st["sddl"] <= t
        ownc2 = jnp.clip(st["so"], 0, B - 1)
        st["snd"] = st["snd"] & ~mask2
        st = record_terminal(st, cn, st["so"], mask2, t,
                             shed_oc(st, ownc2), st["sec"])
        st = count_sheds(st, mask2, ownc2)
        st = sim_clear(st, mask2 & (st["sm"] >= 0))
        return release(st, mask2)

    def phase_faults(st, cn, t):
        """Host step 1f: engine fault transitions at exactly t (their
        times force their own clock events, so transitions apply at
        t == fault time — unlike annotation swaps' strictly-past rule;
        downs before ups at one instant, per `FaultSchedule.events`).
        A down transition checkpoints every in-service stage on the dead
        engine into the paused buffer with stage model -1 ("replan on
        admit"), charging one retry attempt; an exhausted budget fails
        the request terminally.  Preempted stages whose paused calendar
        entry sat on the dead engine convert to replan-on-admit with the
        attempt charged but no exhaustion check (the host's lenient
        rule).  The availability mask ``av`` feeds the in-trace
        blocked-depth recompute at the next replan."""
        if not cfg.fault_outages:
            return st
        B = cn["arr"].shape[0]
        F = cn["ftt"].shape[0] - 1  # trailing +inf pad

        def cond(s):
            return cn["ftt"][jnp.clip(s["fi"], 0, F)] <= t

        def body(s):
            cur = jnp.clip(s["fi"], 0, F)
            ei = cn["fte"][cur]
            up = cn["ftu"][cur]
            s = dict(s)
            s["fi"] = s["fi"] + 1
            s["av"] = s["av"].at[ei].set(up)
            s["frc"] = s["frc"] + jnp.where(up, 1, 0)
            s["foc"] = s["foc"] + jnp.where(up, 0, 1)

            def hit_mask(s2):
                insvc = (s2["so"] >= 0) & (s2["sm"] >= 0)
                return insvc & (cn["eom"][jnp.clip(s2["sm"], 0, M - 1)]
                                == ei)

            def vbody(s2):
                # victims checkpoint one at a time in ascending slot
                # order (paused_insert is a sequential buffer mutation —
                # same order as the host's nonzero() sweep)
                hit = hit_mask(s2)
                slot = jnp.argmax(hit)
                onehot_c = jnp.arange(C) == slot
                i = s2["so"][slot]
                d = cn["depth"][s2["su"][slot]]
                ec = s2["sec"][slot]
                dg = s2["sdg"][slot]
                uu = s2["su"][slot]
                s2 = dict(s2)
                s2["fck"] = s2["fck"] + 1
                s2["rfl"] = s2["rfl"].at[i].set(True)
                s2["rpat"] = s2["rpat"].at[i, d].add(1)
                exhausted = s2["rpat"][i, d] > cfg.max_retries
                s2 = sim_clear(s2, onehot_c)

                def fail_out(ss):
                    ss = record_terminal(
                        ss, cn, jnp.full(1, i, i32), jnp.full(1, True), t,
                        jnp.full(1, _OC_FAILED, i32), jnp.full(1, ec))
                    ss = dict(ss)
                    ss["ffc"] = ss["ffc"] + 1
                    return ss

                def checkpoint(ss):
                    ss = dict(ss)
                    ss["rpu"] = ss["rpu"].at[i].set(uu)
                    ss["rpm"] = ss["rpm"].at[i].set(-1)
                    ss["rpok"] = ss["rpok"].at[i].set(False)
                    ss["rprm"] = ss["rprm"].at[i].set(0.0)
                    ss["rpec"] = ss["rpec"].at[i].set(ec)
                    ss["rpdg"] = ss["rpdg"].at[i].set(dg)
                    return paused_insert(ss, cn, i, cn["cls"][i])

                s2 = lax.cond(exhausted, fail_out, checkpoint, s2)
                return release(s2, onehot_c)

            def on_down(s2):
                s2 = lax.while_loop(lambda ss: hit_mask(ss).any(),
                                    vbody, s2)
                if cfg.priorities:
                    conv = s2["rpp"] & (s2["rpm"] >= 0) & (
                        cn["eom"][jnp.clip(s2["rpm"], 0, M - 1)] == ei)
                    dconv = jnp.clip(cn["depth"][s2["rpu"]], 0,
                                     cfg.max_depth - 1)
                    idx = jnp.arange(B)
                    s2 = dict(s2)
                    s2["rfl"] = s2["rfl"] | conv
                    s2["rpat"] = s2["rpat"].at[
                        jnp.where(conv, idx, B), dconv].add(1, mode="drop")
                    s2["rpm"] = jnp.where(conv, -1, s2["rpm"])
                    s2["rprm"] = jnp.where(conv, 0.0, s2["rprm"])
                return s2

            return lax.cond(up, lambda ss: ss, on_down, s)

        return lax.while_loop(cond, body, st)

    def phase_retry_release(st, cn, t):
        """Host step 1r: slots whose retry backoff expired rejoin the
        replan set — the re-root routes the retry wherever the planner
        now prefers (including around a still-down engine)."""
        if not cfg.fault_failures:
            return st
        rel = st["srt"] <= t
        return {**st, "srt": jnp.where(rel, jnp.inf, st["srt"]),
                "snd": st["snd"] | rel}

    def phase_arrivals(st, cn, t):
        B = cn["arr"].shape[0]

        def cond(s):
            return (s["ap"] < B) & (
                cn["arrs"][jnp.clip(s["ap"], 0, B - 1)] <= t)

        def body(s):
            k = cn["clsord"][jnp.clip(s["ap"], 0, B - 1)]
            return {**s, "ap": s["ap"] + 1,
                    "qt": s["qt"].at[k].add(1)}

        return lax.while_loop(cond, body, st)

    def phase_queue_rejections(st, cn, t):
        if not (pol.gates or cfg.deadline_sheds):
            return st
        if not pol.wants_forecast:
            # paused entries die only by deadline (shed, not reject)
            if paused_on and cfg.deadline_sheds:
                st = shed_paused_rows(st, cn, t, paused_doom(st, cn, t))
            if not pol.gates:
                return st
            # rejection is a prefix of each class ring: elapsed decreases
            # along the ring while the class cap is constant
            B = cn["arr"].shape[0]
            for k in range(K):
                def cond(s, k=k):
                    h = s["qh"][k]
                    i = cn["members"][k, jnp.clip(h, 0, B - 1)]
                    cap = cn["cap"][i]
                    return (h < s["qt"][k]) & jnp.isfinite(cap) & (
                        t - cn["arr"][i]
                        > cap - pol.min_path_lat + pol.margin)

                def body(s, k=k):
                    i = cn["members"][k, jnp.clip(s["qh"][k], 0, B - 1)]
                    one = jnp.full(1, i, i32)
                    tt = jnp.full(1, True)
                    s = record_terminal(s, cn, one, tt, t,
                                        jnp.full(1, _OC_REJECTED, i32),
                                        jnp.zeros(1, s["sec"].dtype))
                    s["rad"] = s["rad"].at[i].set(t)
                    s["rej"] = s["rej"] + 1
                    return {**s, "qh": s["qh"].at[k].add(1)}

                st = lax.while_loop(cond, body, st)
            return st
        return predictive_scan(st, cn, t)

    def predictive_scan(st, cn, t):
        """Host 2b under predictive admission: one pass over the merged
        (class weight, arrival seq) queue order, handing the k-th *kept*
        entry behind the free slots the k-th projected completion —
        positions matter, so rejection is no longer a ring prefix and
        rejected entries are tombstoned in the ``dead`` mask instead."""
        B = cn["arr"].shape[0]
        n_free = jnp.sum(jnp.where(st["sfree"], 1, 0))
        act = st["je"] >= 0
        if cfg.ps:
            jr = job_rates(st, cn)
            tc = st["tl"] + jnp.maximum(st["jrm"], 0.0) \
                / jnp.where(act, jr, 1.0)
        else:
            tc = st["jtc"]
        proj = jnp.sort(jnp.where(act, tc, jnp.inf))
        nproj = jnp.sum(jnp.where(act, 1, 0))
        proj_last = proj[jnp.clip(nproj - 1, 0, C - 1)]

        big = jnp.iinfo(jnp.int64).max

        def heads(s):
            """Scan-local heads: paused cursor first (lower seqs), then
            the fresh cursor (skipping prior tombstones)."""
            out = []
            for k in range(K):
                if cfg.priorities:
                    on_p = s["ppi"][k] < s["pn"][k]
                    p_req = s["pb"][k, jnp.clip(s["ppi"][k], 0, P - 1)]
                else:
                    on_p = jnp.asarray(False)
                    p_req = jnp.asarray(0, i32)
                fh = s["pfh"][k]
                f_ok = fh < s["qt"][k]
                f_req = cn["members"][k, jnp.clip(fh, 0, B - 1)]
                valid = on_p | f_ok
                req = jnp.where(on_p, p_req, f_req)
                out.append((valid, req, on_p))
            return out

        def cond(s):
            any_v = jnp.asarray(False)
            for valid, _, _ in heads(s):
                any_v = any_v | valid
            return any_v

        def body(s):
            hs = heads(s)
            best_k = jnp.asarray(-1, i32)
            best_w = jnp.asarray(-jnp.inf, st["sec"].dtype)
            best_s = jnp.asarray(big, jnp.int64)
            best_r = jnp.asarray(0, i32)
            best_p = jnp.asarray(False)
            for k, (valid, req, on_p) in enumerate(hs):
                sq = jnp.where(valid, cn["seq"][req], big)
                w = jnp.where(valid, cn["wcls"][k], -jnp.inf)
                better = valid & ((w > best_w)
                                  | ((w == best_w) & (sq < best_s)))
                best_k = jnp.where(better, k, best_k)
                best_w = jnp.where(better, w, best_w)
                best_s = jnp.where(better, sq, best_s)
                best_r = jnp.where(better, req, best_r)
                best_p = jnp.where(better, on_p, best_p)
            i = best_r
            onehot = jnp.arange(K) == best_k
            # paused head: deadline-certainty shed or keep
            if cfg.priorities and cfg.deadline_sheds:
                doom_p = best_p & paused_doom(s, cn, t)(i)
            else:
                doom_p = jnp.asarray(False)
            # fresh head: forecast-gated rejection
            j = s["pos"] - n_free
            use_wf = (j >= 0) & (nproj > 0)
            nproj_s = jnp.where(nproj > 0, nproj, 1)
            g = (j // nproj_s).astype(st["sec"].dtype)
            rix = jnp.clip(j % nproj_s, 0, C - 1)
            wf = jnp.where(use_wf, jnp.maximum(
                0.0, proj[rix] - t + g * (proj_last - t)), 0.0)
            cap = cn["cap"][i]
            rej = ~best_p & jnp.isfinite(cap) & (
                t - cn["arr"][i] + pol.discount * wf
                > cap - pol.min_path_lat + pol.margin)
            kept = ~doom_p & ~rej
            one = jnp.full(1, i, i32)
            ec_term = jnp.where(doom_p, s["rpec"][i], 0.0) \
                if cfg.priorities else jnp.asarray(0.0, st["sec"].dtype)
            s = record_terminal(
                s, cn, one, jnp.full(1, doom_p | rej), t,
                jnp.full(1, jnp.where(doom_p, _OC_SHED, _OC_REJECTED), i32),
                jnp.full(1, ec_term))
            s["shd"] = s["shd"] + jnp.where(doom_p, 1, 0)
            s["rej"] = s["rej"] + jnp.where(rej, 1, 0)
            s["rad"] = scat_set(s["rad"], one, t, jnp.full(1, rej))
            if cfg.priorities:
                s["rpp"] = scat_set(s["rpp"], one, False,
                                    jnp.full(1, doom_p))
            s["dead"] = scat_set(s["dead"], one, True, jnp.full(1, rej))
            s["pos"] = s["pos"] + jnp.where(kept, 1, 0)
            # shed paused entries compact out of the buffer; the cursor
            # stays (the next entry slid into its position)
            if cfg.priorities:
                row = s["pb"][best_k]
                iota = jnp.arange(P)
                drop = best_p & doom_p
                comp = jnp.where((iota >= s["ppi"][best_k]) & drop,
                                 jnp.roll(row, -1), row)
                comp = comp.at[P - 1].set(
                    jnp.where(drop, -1, comp[P - 1]))
                s["pb"] = s["pb"].at[best_k].set(comp)
                s["pn"] = s["pn"] - (onehot & drop).astype(s["pn"].dtype)
                s["ppi"] = s["ppi"] + (onehot & best_p & ~doom_p).astype(
                    s["ppi"].dtype)
            s["pfh"] = s["pfh"] + (onehot & ~best_p).astype(s["pfh"].dtype)
            # fresh cursor skips tombstones from earlier events
            for k in range(K):
                def scond(ss, k=k):
                    h = ss["pfh"][k]
                    hr = cn["members"][k, jnp.clip(h, 0, B - 1)]
                    return (h < ss["qt"][k]) & ss["dead"][hr]

                def sbody(ss, k=k):
                    return {**ss, "pfh": ss["pfh"].at[k].add(1)}

                s = lax.while_loop(scond, sbody, s)
            return s

        st = dict(st)
        st["pos"] = jnp.asarray(0, jnp.int64)
        st["pfh"] = st["qh"]
        if cfg.priorities:
            st["ppi"] = jnp.zeros(K, i32)
        st = lax.while_loop(cond, body, st)
        st.pop("pos")
        st.pop("pfh")
        st.pop("ppi", None)
        return skip_dead(st, cn)

    def any_preemptable(st, cn):
        if not (cfg.priorities and cfg.preempt):
            return jnp.asarray(False)
        B = cn["arr"].shape[0]
        valid, _, _, head_w = merged_head(st, cn)
        insvc = (st["so"] >= 0) & (st["sm"] >= 0)
        lower = insvc & (cn["wreq"][jnp.clip(st["so"], 0, B - 1)] < head_w)
        return valid & lower.any()

    def phase_preempt(st, cn, t):
        if not (cfg.priorities and cfg.preempt):
            return st
        B = cn["arr"].shape[0]

        def cond(s):
            return ~s["sfree"].any() & any_preemptable(s, cn)

        def body(s):
            _, _, _, head_w = merged_head(s, cn)
            insvc = (s["so"] >= 0) & (s["sm"] >= 0)
            ownc = jnp.clip(s["so"], 0, B - 1)
            cand = insvc & (cn["wreq"][ownc] < head_w)
            rem = remaining_col(s, t)
            # victim: lexicographic min of (weight, -remaining, slot)
            k1 = jnp.where(cand, cn["wreq"][ownc], jnp.inf)
            c2 = cand & (k1 == jnp.min(k1))
            k2 = jnp.where(c2, -rem, jnp.inf)
            c3 = c2 & (k2 == jnp.min(k2))
            victim = jnp.argmax(c3)
            i = s["so"][victim]
            onehot_c = jnp.arange(C) == victim
            remw = rem[victim]
            s = dict(s)
            s["rpu"] = s["rpu"].at[i].set(s["su"][victim])
            s["rpm"] = s["rpm"].at[i].set(s["sm"][victim])
            s["rpok"] = s["rpok"].at[i].set(s["sok"][victim])
            s["rprm"] = s["rprm"].at[i].set(remw)
            s["rpec"] = s["rpec"].at[i].set(s["sec"][victim])
            s["rpdg"] = s["rpdg"].at[i].set(s["sdg"][victim])
            s["pre"] = s["pre"] + 1
            s["rpc"] = s["rpc"].at[i].add(1)
            s = sim_clear(s, onehot_c)
            s = release(s, onehot_c)
            return paused_insert(s, cn, i, cn["cls"][i])

        return lax.while_loop(cond, body, st)

    def phase_admit(st, cn, t):
        B = cn["arr"].shape[0]

        def cond(s):
            valid, _, _, _ = merged_head(s, cn)
            return s["sfree"].any() & valid

        def body(s):
            _, k_idx, i, _ = merged_head(s, cn)
            slot = jnp.argmax(s["sfree"])
            onehot_c = jnp.arange(C) == slot
            s = pop_head(s, cn, k_idx)
            s = dict(s)
            s["so"] = jnp.where(onehot_c, i, s["so"])
            s["sfree"] = s["sfree"] & ~onehot_c
            # fresh admission and paused resume, composed with masks
            # (each writes the union of the host branches' columns; the
            # non-taken branch writes the value the host left in place)
            if paused_on:
                isp = s["rpp"][i]
                # outage checkpoints carry stage model -1: restore the
                # realized prefix and budgets, then REPLAN instead of
                # resuming a calendar entry (host `resume`, pm < 0)
                isrp = (isp & (s["rpm"][i] < 0)) if cfg.fault_outages \
                    else jnp.asarray(False)
                isrs = isp & ~isrp
                s["su"] = jnp.where(onehot_c,
                                    jnp.where(isp, s["rpu"][i], 0), s["su"])
                s["sec"] = jnp.where(onehot_c,
                                     jnp.where(isp, s["rpec"][i], 0.0),
                                     s["sec"])
                s["sm"] = jnp.where(onehot_c & isrs, s["rpm"][i], s["sm"])
                s["sok"] = jnp.where(onehot_c & isp, s["rpok"][i], s["sok"])
                s["sdg"] = jnp.where(onehot_c,
                                     isp & s["rpdg"][i], s["sdg"])
            else:
                isp = jnp.asarray(False)
                isrp = isrs = jnp.asarray(False)
                s["su"] = jnp.where(onehot_c, 0, s["su"])
                s["sec"] = jnp.where(onehot_c, 0.0, s["sec"])
                s["sdg"] = jnp.where(onehot_c, False, s["sdg"])
            if cfg.deadline_sheds:
                t_d = cn["arr"][i] + cn["cap"][i]
                s["sddl"] = jnp.where(
                    onehot_c & jnp.isfinite(t_d) & (t_d > t),
                    t_d, s["sddl"])
            if paused_on:
                s["rpp"] = s["rpp"].at[i].set(False)
                # resume: restart the paused stage on the calendar with
                # the checkpointed remaining work (no replan); replan-on-
                # admit checkpoints skip the calendar entirely
                w = cn["wreq"][i]
                eng = cn["eom"][jnp.clip(s["rpm"][i], 0, M - 1)]
                s["je"] = jnp.where(onehot_c & isrs, eng, s["je"])
                if cfg.ps:
                    s["jrm"] = jnp.where(onehot_c & isrs,
                                         s["rprm"][i], s["jrm"])
                else:
                    s["jtc"] = jnp.where(onehot_c & isrs,
                                         t + s["rprm"][i], s["jtc"])
                    s["jwk"] = jnp.where(onehot_c & isrs,
                                         s["rprm"][i], s["jwk"])
                s["jw"] = jnp.where(onehot_c & isrs, w, s["jw"])
                s["wtd"] = s["wtd"] | (isrs & (w != 1.0))
                s["jsq"] = jnp.where(onehot_c & isrs, s["ns"], s["jsq"])
                s["ns"] = s["ns"] + jnp.where(isrs, 1, 0)
                s["res"] = s["res"] + jnp.where(isrs, 1, 0)
                s = lax.cond(isrs, lambda ss: peak_update(ss, cn),
                             lambda ss: ss, s)
            s["rad"] = jnp.where(isp, s["rad"],
                                 s["rad"].at[i].set(t))
            s["adm"] = s["adm"] + jnp.where(isp, 0, 1)
            s["snd"] = s["snd"] | (onehot_c & (~isp | isrp))
            return s

        return lax.while_loop(cond, body, st)

    def phase_replan_dispatch(st, cn, t):
        """Host steps 4-5b: ONE planner call over all capacity lanes,
        downgrade-lane override, vectorized dispatch, overload trim."""
        B = cn["arr"].shape[0]
        st = dict(st)
        st["rp"] = st["rp"] + 1
        ownc = jnp.clip(st["so"], 0, B - 1)
        el = t - cn["arr"][ownc]
        if cfg.priorities:
            el = el + cn["shift"][ownc]
        el32 = el.astype(jnp.float32)
        ec32 = st["sec"].astype(jnp.float32)
        delay_row = jnp.zeros(E, jnp.float32)
        if cfg.load_aware:
            act = st["je"] >= 0
            park = jnp.where(act, jnp.clip(st["je"], 0, E - 1), E)
            if cfg.tokens:
                # TokenWorkModel.delays over the live sequence COUNT (the
                # KV/batch physics depends on how many sequences share the
                # decode step, never on priority weights); slowdown mirror
                # of EngineTokenModel.slowdown with the same barriers as
                # traced_token_rates so host == compiled bitwise
                occw = jnp.zeros(E + 1, st["sec"].dtype).at[park].add(
                    jnp.where(act, 1.0, 0.0))[:E]
                n = occw + 1.0
                b = jnp.minimum(n, cn["tkc"])
                prod = lax.optimization_barrier(cn["tkv"] * b)
                sb = jnp.maximum(cn["tkw"] + prod, cn["tkf"] * b)
                q1 = lax.optimization_barrier(n / b)
                q2 = lax.optimization_barrier(sb / cn["tk1"])
                sd = lax.optimization_barrier(q1 * q2)
                dr64 = (sd - 1.0) * cn["ms"]
                # the host casts the dict values into a float32 row first
                delay_row = jnp.where(cn["hasm"], dr64,
                                      0.0).astype(jnp.float32)
            elif cfg.ps:
                # FleetLoadModel.delays over the live (weighted) occupancy
                occw = jnp.zeros(E + 1, st["sec"].dtype).at[park].add(
                    jnp.where(act,
                              st["jw"] if cfg.priorities else 1.0, 0.0))[:E]
                dr64 = (jnp.maximum(1.0, (occw + 1.0) / cn["conc"]) - 1.0) \
                    * cn["ms"]
                # the host casts the dict values into a float32 row first
                delay_row = jnp.where(cn["hasm"], dr64,
                                      0.0).astype(jnp.float32)
            if pol.wants_forecast and pol.backlog_delay > 0.0:
                # backlog-drain anchor (PredictiveGate.forecast_delay_row):
                # max against the float32 row in float64, like the host
                if cfg.ps:
                    rem = jnp.where(act, jnp.maximum(st["jrm"], 0.0), 0.0)
                    jr = jnp.where(act, job_rates(st, cn), 0.0)
                else:
                    rem = jnp.where(act,
                                    jnp.maximum(st["jtc"] - t, 0.0), 0.0)
                    jr = jnp.where(act, 1.0, 0.0)
                backlog = jnp.zeros(E + 1, rem.dtype).at[park].add(rem)[:E]
                rate = jnp.zeros(E + 1, rem.dtype).at[park].add(jr)[:E]
                drain = jnp.where(rate > 0, backlog / rate, 0.0)
                delay_row = jnp.maximum(
                    delay_row.astype(st["sec"].dtype),
                    pol.backlog_delay * drain).astype(jnp.float32)
        if cfg.fault_outages:
            # blocked-depth column from the live availability mask: the
            # planner admits target v iff bd[v] <= depth[u], i.e. every
            # stage strictly past the realized node runs on an up engine
            # (host blocked_depth_table, recomputed per fault transition;
            # here recomputed in-trace each replan — the mask is a traced
            # operand, so outages cause ZERO new planner programs)
            pmn = cn["td"].path_models
            deadp = (pmn >= 0) & ~st["av"][
                cn["eom"][jnp.clip(pmn, 0, M - 1)]]
            posn = jnp.arange(pmn.shape[1])[None, :]
            bd = jnp.max(jnp.where(deadp, posn + 1, 0),
                         axis=1, initial=0).astype(jnp.float32)
        else:
            bd = None
        need = st["snd"]
        if cfg.n_shards > 1:
            # Sharded control plane: every device keeps the full replicated
            # bookkeeping (the event loop is sequential and globally
            # coupled), but the expensive part of a replan round — the
            # per-lane trie sweeps below — is partitioned by residue class
            # ``lane % n_shards == axis_index``.  Each device plans only
            # its own needy lanes; the one `psum` after the sweep is the
            # ONLY cross-device collective per replan round and carries the
            # planned (target, next-model) pair back to every device.
            # Lane-independence of the planner (see the sweep comment
            # below) makes the merged result bit-identical to the
            # single-device sweep.
            mine = need & ((jnp.arange(C) % cfg.n_shards)
                           == lax.axis_index(LANE_AXIS))
        else:
            mine = need

        # Plan ONLY the lanes that need dispatch, one width-1 kernel sweep
        # per lane: the planner's math is lane-independent (per-request
        # running minima over node tiles, identical tiling at any batch
        # width), so the single-lane call is bit-identical to that lane of
        # a capacity-wide call — but a steady-state event has 1-2 needy
        # lanes, so this trades C full-trie sweeps for n_needed and is
        # what makes the engine trie-size-robust (the batched form was
        # ~C x slower per event on the 5461-node MathQA trie).  Downgraded
        # lanes pick the min-cost scalar bundle per lane instead of a
        # second capacity-wide sweep (the host uses a float64 search;
        # divergence is possible at float32 resolution and documented in
        # EVENT_ENGINE.md).
        def plan_lane(c):
            tgt, nxt, done = c
            i = jnp.argmax(mine & ~done)
            pre1 = lax.dynamic_slice_in_dim(st["su"], i, 1)
            el1 = lax.dynamic_slice_in_dim(el32, i, 1)
            ec1 = lax.dynamic_slice_in_dim(ec32, i, 1)
            t1, n1 = traced_fleet_plan(cn["td"], pre1, el1, ec1,
                                       delay_row, cn["sc"],
                                       kind=cfg.kind, variant=cfg.variant,
                                       blocked=bd)
            if pol.max_occupancy is not None and pol.downgrade:
                dg1 = lax.dynamic_slice_in_dim(st["sdg"], i, 1)[0]
                t1, n1 = lax.cond(
                    dg1,
                    lambda a: traced_fleet_plan(cn["td"], *a, cn["scdg"],
                                                kind=cfg.kind_dg,
                                                variant=cfg.variant),
                    lambda a: (t1, n1), (pre1, el1, ec1, delay_row))
            tgt = lax.dynamic_update_slice_in_dim(tgt, t1, i, 0)
            nxt = lax.dynamic_update_slice_in_dim(nxt, n1, i, 0)
            return tgt, nxt, done.at[i].set(True)

        tgt, nxt, _ = lax.while_loop(
            lambda c: (mine & ~c[2]).any(), plan_lane,
            (jnp.full(C, -1, i32), jnp.full(C, -1, i32),
             jnp.zeros(C, bool)))
        if cfg.n_shards > 1:
            # the one collective per replan round: lanes are shifted +1 so
            # an owner's infeasible plan (-1) and a non-owner's zero both
            # decode to -1 after the sum (each needy lane has exactly one
            # owner, so the sum IS the owner's value)
            enc = lax.psum(jnp.stack([jnp.where(mine, tgt + 1, 0),
                                      jnp.where(mine, nxt + 1, 0)]),
                           LANE_AXIS)
            tgt = jnp.where(need, enc[0] - 1, -1)
            nxt = jnp.where(need, enc[1] - 1, -1)
        if cfg.explore:
            # exploration lane (host 4c): a pre-drawn request's FIRST
            # dispatch (root prefix) overrides the planner's pick with
            # its explore model, iff the float32 budget guard passes
            # against the live annotation version.  Same op order as the
            # host guard (subtract, add, compare — all exact IEEE f32),
            # applied after the downgrade lane, elementwise on replicated
            # values (no collective).
            xm = cn["xpm"][ownc]
            xv = cn["child"][0, jnp.clip(xm, 0, M - 1)]
            xvc = jnp.clip(xv, 0, cn["td"].lat.shape[0] - 1)
            ok = (need & (nxt >= 0) & (st["su"] == 0) & (xm >= 0)
                  & (el32 + (cn["td"].lat[xvc] - cn["td"].lat[0])
                     <= cn["sc"][2])
                  & (ec32 + (cn["td"].cost[xvc] - cn["td"].cost[0])
                     <= cn["sc"][1]))
            if cfg.fault_outages:
                # host 4c skips the explore override when the explore
                # model's engine is down
                ok = ok & st["av"][cn["eom"][jnp.clip(xm, 0, M - 1)]]
            nxt = jnp.where(ok, xm, nxt)
            st["xpc"] = st["xpc"] + jnp.sum(jnp.where(ok, 1, 0))
        stop = need & (nxt < 0)
        infeas = stop & (tgt < 0)
        oc = jnp.full(C, _OC_SERVED, i32)
        if pol.gates:
            started = cn["depth"][st["su"]] > 0
            shed_m = infeas & started
            rej_m = infeas & ~started
            if fault_any:
                # a fault-touched request that becomes infeasible is a
                # FAILURE, not a shed/reject (host classify conversion)
                flt = st["rfl"][ownc]
                fail_m = infeas & flt
                shed_m = shed_m & ~flt
                rej_m = rej_m & ~flt
                oc = jnp.where(fail_m, _OC_FAILED, oc)
                st["ffc"] = st["ffc"] + jnp.sum(jnp.where(fail_m, 1, 0))
            oc = jnp.where(shed_m, _OC_SHED, oc)
            oc = jnp.where(rej_m, _OC_REJECTED, oc)
            st["shd"] = st["shd"] + jnp.sum(jnp.where(shed_m, 1, 0))
            n_rej = jnp.sum(jnp.where(rej_m, 1, 0))
            st["rej"] = st["rej"] + n_rej
            st["adm"] = st["adm"] - n_rej
        st = record_terminal(st, cn, st["so"], stop, t, oc, st["sec"])
        start_m = need & (nxt >= 0)
        if cfg.fault_failures:
            # seeded stage-failure draws, indexed per (request, depth,
            # attempt) and consulted BEFORE the executor charges cost
            # (host dispatch gate).  A drawn failure bumps the attempt
            # counter; exhaustion fails the request terminally, otherwise
            # the slot is held for t + backoff(attempt) and replanned.
            mr = cfg.max_retries
            d0 = cn["depth"][st["su"]]
            d0c = jnp.clip(d0, 0, cfg.max_depth - 1)
            a0 = st["rpat"][ownc, d0c]
            draw = start_m & cn["fdr"][ownc, d0c,
                                       jnp.clip(a0, 0, mr)]
            scat = jnp.where(draw, ownc, B)
            st["rpat"] = st["rpat"].at[scat, d0c].add(1, mode="drop")
            st["rfl"] = st["rfl"].at[scat].set(True, mode="drop")
            a1 = a0 + 1
            exh = draw & (a1 > mr)
            retry = draw & ~exh
            st["fsc"] = st["fsc"] + jnp.sum(jnp.where(draw, 1, 0))
            st["frt"] = st["frt"] + jnp.sum(jnp.where(retry, 1, 0))
            st["ffc"] = st["ffc"] + jnp.sum(jnp.where(exh, 1, 0))
            nb = cn["fbo"].shape[0]
            st["srt"] = jnp.where(
                retry, t + cn["fbo"][jnp.clip(a0, 0, nb - 1)], st["srt"])
            st = record_terminal(st, cn, st["so"], exh, t,
                                 jnp.full(C, _OC_FAILED, i32), st["sec"])
            st = release(st, exh)
            start_m = start_m & ~draw
        d = cn["depth"][st["su"]]
        row = cn["row"][ownc]
        nxtc = jnp.clip(nxt, 0, M - 1)
        sres = cn["tabs"][row, d, nxtc]
        c = cn["tabc"][row, d, nxtc]
        lat = cn["tabl"][row, d, nxtc]
        st["sec"] = jnp.where(start_m, st["sec"] + c, st["sec"])
        st["sm"] = jnp.where(start_m, nxt, st["sm"])
        st["sok"] = jnp.where(start_m, sres, st["sok"])
        # calendar starts, seq assigned in ascending slot order
        rank = jnp.cumsum(jnp.where(start_m, 1, 0)) - 1
        st["jsq"] = jnp.where(start_m, st["ns"] + rank, st["jsq"])
        st["ns"] = st["ns"] + jnp.sum(jnp.where(start_m, 1, 0))
        st["je"] = jnp.where(start_m, cn["eom"][nxtc], st["je"])
        if cfg.ps:
            st["jrm"] = jnp.where(start_m, lat, st["jrm"])
        else:
            st["jtc"] = jnp.where(start_m, t + lat, st["jtc"])
            st["jwk"] = jnp.where(start_m, lat, st["jwk"])
        if cfg.priorities:
            w = cn["wreq"][ownc]
            st["jw"] = jnp.where(start_m, w, st["jw"])
            st["wtd"] = st["wtd"] | (start_m & (w != 1.0)).any()
        st = release(st, stop)
        st = peak_update(st, cn)
        st["snd"] = jnp.zeros(C, bool)
        if pol.max_occupancy is not None:
            st = phase_overload(st, cn, t)
        return st

    def phase_overload(st, cn, t):
        """Host 5b: per engine over its occupancy target, iteratively trim
        the lowest goodput-per-token jobs (downgrade first, shed when
        already downgraded) — CostAwareShed.overload_actions."""
        maxo = pol.max_occupancy
        for e in range(E):
            def on_engine(s):
                insvc = (s["so"] >= 0) & (s["sm"] >= 0)
                return insvc & (cn["eom"][jnp.clip(s["sm"], 0, M - 1)] == e)

            n0 = jnp.sum(jnp.where(on_engine(st), 1, 0))
            excess = n0 - maxo

            def cond(c):
                s, taken, cnt = c
                return cnt < excess

            def body(c):
                s, taken, cnt = c
                cand = on_engine(s) & ~taken
                acc = cn["bacc"][s["su"]]
                remc = jnp.maximum(cn["mcost"][s["su"]] - s["sec"], 0.0)
                score = jnp.where(
                    jnp.isfinite(acc),
                    jnp.maximum(acc, 0.0) / (s["sec"] + remc + 1e-9),
                    -jnp.inf)
                key = jnp.where(cand, score, jnp.inf)
                pick = cand & (key == jnp.min(key))
                victim = jnp.argmax(pick)
                onehot_c = jnp.arange(C) == victim
                dg = pol.downgrade & ~s["sdg"][victim]
                s = dict(s)
                s["sdg"] = jnp.where(onehot_c & dg, True, s["sdg"])
                s["dgc"] = s["dgc"] + jnp.where(dg, 1, 0)
                shed_m = onehot_c & ~dg
                s = record_terminal(s, cn, s["so"], shed_m, t,
                                    jnp.full(C, _OC_SHED, i32), s["sec"])
                s["shd"] = s["shd"] + jnp.where(dg, 0, 1)
                s = sim_clear(s, shed_m)
                s = release(s, shed_m)
                return s, taken | onehot_c, cnt + 1

            st, _, _ = lax.while_loop(
                cond, body, (st, jnp.zeros(C, bool), jnp.asarray(0, "int64")))
        return st

    def next_event_time(st, cn):
        B = cn["arr"].shape[0]
        t_arr = jnp.where(st["ap"] < B,
                          cn["arrs"][jnp.clip(st["ap"], 0, B - 1)], jnp.inf)
        tn = jnp.minimum(t_arr, next_completion(st, cn))
        tn = jnp.minimum(tn, jnp.min(st["sddl"]))
        if cfg.fault_outages:
            F = cn["ftt"].shape[0] - 1
            tn = jnp.minimum(tn, cn["ftt"][jnp.clip(st["fi"], 0, F)])
        if cfg.fault_failures:
            tn = jnp.minimum(tn, jnp.min(st["srt"]))
        if paused_on and cfg.deadline_sheds:
            req = jnp.clip(st["pb"], 0, B - 1)
            activep = jnp.arange(P)[None, :] < st["pn"][:, None]
            pddl = jnp.where(activep,
                             cn["arr"][req] + cn["cap"][req], jnp.inf)
            tn = jnp.minimum(tn, jnp.min(pddl))
        return tn

    def event_body(st, cn):
        t = st["tn"]
        st = {**st, "ev": st["ev"] + 1, "snd": jnp.zeros(C, bool)}
        if cfg.ps:
            act = st["je"] >= 0
            tok = (cn["tkw"], cn["tkv"], cn["tkf"], cn["tkc"],
                   cn["tk1"]) if cfg.tokens else None
            jrm, tl = traced_advance(st["jrm"], st["tl"], t, st["je"],
                                     st["jw"], act, cn["conc"], st["wtd"],
                                     tok=tok)
            st = {**st, "jrm": jrm, "tl": tl}
        st = phase_completions(st, cn, t)
        if cfg.fault_outages:
            st = phase_faults(st, cn, t)
        st = phase_deadline_sheds(st, cn, t)
        st = phase_arrivals(st, cn, t)
        st = phase_queue_rejections(st, cn, t)
        if cfg.fault_failures:
            st = phase_retry_release(st, cn, t)

        # 3-5 cycle: preempt -> admit/resume -> replan -> dispatch,
        # repeated while freed slots can absorb queued arrivals
        def cyc_cond(c):
            st_, go = c
            return go

        def cyc_body(c):
            s, _ = c
            s = phase_preempt(s, cn, t)
            s = phase_admit(s, cn, t)
            need_any = s["snd"].any()
            s = lax.cond(need_any,
                         lambda ss: phase_replan_dispatch(ss, cn, t),
                         lambda ss: ss, s)
            valid, _, _, _ = merged_head(s, cn)
            again = jnp.where(
                need_any,
                (s["sfree"].any() & valid) | any_preemptable(s, cn),
                any_preemptable(s, cn))
            return s, again

        st, _ = lax.while_loop(cyc_cond, cyc_body,
                               (st, jnp.asarray(True)))
        return {**st, "tn": next_event_time(st, cn)}

    def step(st, cn, t_hi):
        def cond(s):
            return jnp.isfinite(s["tn"]) & (s["tn"] <= t_hi)

        return lax.while_loop(cond, lambda s: event_body(s, cn), st)

    if cfg.n_shards > 1:
        # SPMD wrapper: every operand and result is REPLICATED (empty
        # PartitionSpec) — the sequential event loop's bookkeeping must be
        # identical on every device so the outer while_loops take the same
        # trip counts everywhere (a collective inside a device-varying
        # loop would deadlock).  What the mesh buys is the replan sweep:
        # each device walks only its residue class of needy lanes
        # (collective-free inner while_loop — device-varying trip counts
        # are legal there), and one psum per replan round rebroadcasts the
        # merged plans.  check_rep=False because jax cannot prove the
        # psum output replicated through the surrounding loops.
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PSpec

        from repro.dist.sharding import lane_mesh
        rep = PSpec()
        step = shard_map(step, mesh=lane_mesh(cfg.n_shards),
                         in_specs=(rep, rep, rep), out_specs=rep,
                         check_rep=False)
    jitted = jax.jit(step, donate_argnums=(0,))
    _ENGINE_CACHE[cfg] = jitted
    return jitted


def _tabulate_executor(executor: StageExecutor, requests: np.ndarray,
                       probe: np.ndarray, t_start: float,
                       work_model=None, engines=None,
                       engine_of_model=None):
    """Evaluate the executor over (unique request value, depth, model)
    once, producing the dense (U, D, M) tables the traced dispatch
    gathers from.  This is what makes executors compilable — and why the
    compiled engine requires them to be pure functions of that triple
    (the host loop passes the live event time; here every cell is probed
    at ``t_start``).  ``probe`` is a (D, M) bool mask of the (depth,
    model) pairs the trie can actually dispatch — only those cells are
    evaluated, so executors (like the oracle's) that index stage tables
    by depth never see out-of-range probes; unreachable cells stay at
    benign zeros and are masked out of every traced use.

    Under a ``work_model`` (token calendar, ISSUE 10) the latency cell is
    the stage's token footprint in batch-1 seconds — the same host-side
    `TokenWorkModel.work_of` the host loop calls at dispatch, so the two
    calendars start from bit-identical work quanta; the requirement that
    ``stage_tokens`` be a pure function of (request, depth, model) is what
    makes the tabulation valid."""
    uniq, row = np.unique(requests, return_inverse=True)
    U = uniq.shape[0]
    D, M = probe.shape
    tab_s = np.zeros((U, D, M), dtype=bool)
    tab_c = np.zeros((U, D, M), dtype=np.float64)
    tab_l = np.zeros((U, D, M), dtype=np.float64)
    for ui, rv in enumerate(uniq):
        for d, m in zip(*np.nonzero(probe)):
            s, c, lat = executor(int(rv), int(d), int(m), t_start)
            if work_model is not None:
                ptok, dtok = work_model.stage_tokens(int(rv), int(d),
                                                     int(m))
                lat = work_model.work_of(
                    engines[int(engine_of_model[int(m)])], ptok, dtok)
            tab_s[ui, d, m] = bool(s)
            tab_c[ui, d, m] = float(c)
            tab_l[ui, d, m] = float(lat)
    return tab_s, tab_c, tab_l, row.astype(np.int32)


def run_events_compiled(
    trie: Trie,
    ann: TrieAnnotations,
    obj: Objective,
    requests: np.ndarray,
    executor: StageExecutor,
    *,
    arrivals: np.ndarray | None = None,
    capacity: int | None = None,
    policy: str = "dynamic",
    admission=None,
    classes: np.ndarray | None = None,
    class_specs=None,
    preempt: bool = True,
    restrict_nodes: np.ndarray | None = None,
    load_probe=None,
    fleet_load=None,
    work_model=None,
    t_start: float = 0.0,
    plan_variant: str | None = None,
    annotation_schedule=None,
    refresh=None,
    explore=None,
    faults=None,
    epoch: int = DEFAULT_EPOCH,
    stream: bool = False,
    devices: int | None = None,
) -> tuple[list[ExecutionResult], EventStats]:
    """Compiled twin of `repro.core.events.run_events` (same signature
    plus ``epoch``/``stream``/``devices``); see that function for the
    serving semantics — the two are bit-compatible on the differential
    oracle.

    ``epoch`` sets how many arrivals each jitted step ingests before the
    host drains progress scalars (a throughput/latency knob; any value
    gives identical results and hits the same compiled program).  With
    ``stream=True`` the per-request result list is NOT materialized:
    the call returns ``(summary_dict, EventStats)`` where the summary
    carries the streaming Welford moments, quantile histogram and
    counters — constant host memory regardless of trace length (the
    1M-request replay path, `benchmarks/trace_replay.py`).

    ``devices`` shards the control plane's replan sweeps over a 1-D lane
    mesh (`repro.dist.sharding.lane_mesh`): each device plans only the
    needy lanes in its residue class and one `psum` per replan round
    merges the plans — bit-identical dispositions and summaries at any
    device count (docs/EVENT_ENGINE.md, "Sharding").  ``None``/``1``
    keeps the single-device program unchanged.  On CPU hosts virtual
    devices come from ``--xla_force_host_platform_device_count``.

    ``annotation_schedule`` swaps in re-annotated `TrieDevice` versions
    mid-run (ISSUE 8): the epoch loop splits at each swap time, so every
    event at ``t <= t_swap`` runs under the old annotations and the swap
    is a pure operand substitution — the annotation columns are traced
    operands, ZERO new compiled programs per swap.  ``explore`` enables
    the same epsilon-greedy exploration lane as the host loop
    (bit-compatible float32 budget guard).  ``refresh`` (the online
    posterior loop) needs host-side service observations and raises
    `NotImplementedError` here — use ``compiled=False`` or a precomputed
    ``annotation_schedule``.

    ``faults`` takes the same `repro.core.faults.FaultSchedule` as the
    host loop and is bit-compatible with it on the chaos differential:
    outage transitions become traced (time, engine, up) operand columns
    whose availability mask feeds the planner's blocked-depth operand
    (ZERO new compiled programs per outage), victims checkpoint into the
    paused buffer as replan-on-admit entries, and seeded stage-failure
    draws gate dispatch with capped exponential backoff.  Unsupported
    here (use the host loop): ``timeout_k`` (needs host-side latency
    forecasts), ``recovery="restart"``, and combining faults with
    forecast/occupancy admission policies.

    ``work_model`` (ISSUE 10) switches the engine calendar to the
    token-level model, bit-compatible with the host loop: stage work is
    tabulated host-side as the (prefill, decode) token footprint in
    batch-1 seconds via `TokenWorkModel.work_of`, and the traced drain
    uses the continuous-batching decode-step rate curve
    (`traced_token_rates`) whose coefficients ride as (E,) operands —
    new token models or curve parameters compile ZERO new programs.
    Requires concrete `TokenWorkModel`/`EngineTokenModel` instances and
    is mutually exclusive with ``fleet_load``/``load_probe``.
    """
    if policy not in ("dynamic", "dynamic_load_aware"):
        raise ValueError(f"unsupported events policy {policy!r}: the static "
                         "baseline plans once per request — use run_cohort's "
                         "scalar path")
    if load_probe is not None:
        raise NotImplementedError(
            "compiled event engine cannot trace a host load_probe callback; "
            "use fleet_load=FleetLoadModel(...) or the host loop")
    if work_model is not None:
        if fleet_load is not None:
            raise ValueError("work_model and fleet_load are mutually "
                             "exclusive: the token calendar replaces the "
                             "scalar slowdown model")
        if getattr(work_model, "stage_tokens", None) is None:
            raise ValueError("work_model.stage_tokens must be set: the "
                             "token calendar needs per-stage "
                             "(prefill, decode) token counts")
        # like fleet_load: the traced calendar needs the concrete
        # decode-step coefficients, not a duck-typed work model
        from repro.serving.loadsim import EngineTokenModel, TokenWorkModel
        if not isinstance(work_model, TokenWorkModel) or not all(
                isinstance(m, EngineTokenModel)
                for m in work_model.engines.values()):
            raise NotImplementedError(
                "compiled event engine supports TokenWorkModel with "
                "EngineTokenModel entries; use the host loop for duck-typed "
                "work models")
    if refresh is not None:
        raise NotImplementedError(
            "compiled event engine cannot run the online estimator refresh "
            "(posterior updates are host-side observations); use the host "
            "loop (compiled=False) or a precomputed annotation_schedule")
    pol = get_policy(admission)
    tpol = traced_admission(pol)  # raises for custom policy subclasses
    fault_outages = faults is not None and bool(faults.outages)
    fault_failures = faults is not None and (
        faults.stage_failure_rate > 0.0 or faults.failure_table is not None)
    if faults is not None:
        if faults.timeout_k is not None:
            raise NotImplementedError(
                "compiled event engine cannot trace the stage-timeout model "
                "(timeout_k needs the host loop's live latency forecasts); "
                "use compiled=False")
        if faults.recovery != "checkpoint":
            raise NotImplementedError(
                f"compiled event engine only supports recovery='checkpoint' "
                f"(got {faults.recovery!r}); restart-from-root is a host-loop "
                "baseline for benchmarks/chaos.py")
        if (fault_outages or fault_failures) and (
                pol.wants_forecast or pol.max_occupancy is not None):
            raise NotImplementedError(
                "compiled event engine does not combine fault injection with "
                "forecast- or occupancy-gated admission policies; use the "
                "host loop (compiled=False)")
    requests = np.asarray(requests)
    B = int(requests.shape[0])
    if arrivals is None:
        arrivals = np.zeros(B, dtype=np.float64)
    else:
        arrivals = np.asarray(arrivals, dtype=np.float64)
        if arrivals.shape != (B,):
            raise ValueError(f"arrivals shape {arrivals.shape} != ({B},)")
        if B and (not np.all(np.isfinite(arrivals)) or arrivals.min() < 0):
            raise ValueError("arrivals must be finite and non-negative")
    if capacity is None:
        capacity = B if arrivals.size == 0 or arrivals.max() == 0.0 \
            else min(B, _DEFAULT_CAPACITY)
    C = int(capacity)
    if B and C < 1:
        raise ValueError("capacity must be >= 1")

    priorities = class_specs is not None
    if not priorities and classes is not None:
        raise ValueError("classes requires class_specs (the SLOClass table "
                         "the indices point into)")
    base_cap = obj.lat_cap if obj.lat_cap is not None else np.inf
    if priorities:
        specs = tuple(class_specs)
        if not specs:
            raise ValueError("class_specs must be a non-empty sequence of "
                             "SLO classes")
        cls_idx = (np.zeros(B, dtype=np.int64) if classes is None
                   else np.asarray(classes, dtype=np.int64))
        if cls_idx.shape != (B,):
            raise ValueError(f"classes shape {cls_idx.shape} != ({B},)")
        if B and (cls_idx.min() < 0 or cls_idx.max() >= len(specs)):
            raise ValueError(
                f"classes must index the {len(specs)} class_specs entries")
        cap_cls = np.array([c.deadline_s if c.deadline_s is not None
                            else base_cap for c in specs], dtype=np.float64)
        w_cls = np.array([c.weight for c in specs], dtype=np.float64)
        cap_req = cap_cls[cls_idx]
        weight_req = w_cls[cls_idx]
        K = len(specs)
    else:
        cls_idx = np.zeros(B, dtype=np.int64)
        cap_req = np.full(B, base_cap)
        weight_req = np.ones(B)
        w_cls = np.ones(1)
        K = 1

    stats = EventStats(capacity=C, policy=pol.name,
                       outcome=[SERVED] * B,
                       arrival_t=arrivals.copy(),
                       admit_t=np.zeros(B, dtype=np.float64),
                       done_t=np.zeros(B, dtype=np.float64),
                       class_of=cls_idx.copy() if priorities else None,
                       preempt_count=np.zeros(B, dtype=np.int64))
    if B == 0:
        return ([], stats) if not stream else (
            _empty_summary(stats), stats)

    td = TrieDevice.build(trie, ann, restrict_nodes)
    swaps: list[tuple[float, TrieDevice]] = []
    if annotation_schedule:
        sched = sorted(annotation_schedule, key=lambda sa: float(sa[0]))
        for i, (ts, swap_ann) in enumerate(sched):
            ts = float(ts)
            if not np.isfinite(ts) or ts < 0:
                raise ValueError(
                    f"annotation_schedule swap time {ts!r} must be finite "
                    "and non-negative")
            swap_td = TrieDevice.build(trie, swap_ann, restrict_nodes)
            swap_td.version = i + 1
            swaps.append((ts, swap_td))
    lat_shift = np.zeros(B)
    eff_cap = None
    if priorities:
        finite = cap_req[np.isfinite(cap_req)]
        eff_cap = float(finite.max()) if finite.size else None
        if eff_cap is not None:
            lat_shift = np.where(np.isfinite(cap_req),
                                 eff_cap - cap_req, -np.inf)
            # same float32 elapsed-shift resolution caveat as the host
            # loop (see run_events): warn when the deadline spread makes
            # the quantization material for the tightest class
            step = float(np.spacing(np.float32(eff_cap)))
            if step > 1e-3 * float(finite.min()):
                warnings.warn(
                    f"class deadline spread ({finite.min():.3g}s .. "
                    f"{eff_cap:.3g}s) exceeds float32 elapsed-shift "
                    f"resolution ({step:.3g}s at the largest cap): the "
                    "planner's feasibility may lag the deadline "
                    "bookkeeping by up to that much for tight classes",
                    stacklevel=2)
    plan_obj = obj if eff_cap is None \
        else dataclasses.replace(obj, lat_cap=eff_cap)
    engines = trie_engines(trie.template)
    E = len(engines)
    M = trie.template.n_models
    max_depth = trie.template.max_depth
    load_aware = policy == "dynamic_load_aware"

    term_mask = trie.terminal.copy()
    if restrict_nodes is not None:
        keep = np.zeros(trie.n_nodes, dtype=bool)
        keep[restrict_nodes] = True
        term_mask &= keep
    pol.bind(trie, ann, obj, term_mask)
    tpol = traced_admission(pol)  # re-distill with bound min_path_lat
    explore_model = _explore_tables(trie, term_mask, B, explore)
    deadline_sheds = pol.shed_on_deadline and bool(
        np.isfinite(cap_req).any())

    # load coupling: the traced calendar needs the concrete
    # EngineLoadModel parameters, not a duck-typed slowdown callable
    conc = np.full(E, np.inf)
    ms = np.ones(E)
    hasm = np.zeros(E, dtype=bool)
    tokens = work_model is not None
    ps = tokens or (load_aware and fleet_load is not None)
    if tokens:
        # token calendar (ISSUE 10): the decode-step curve coefficients
        # become (E,) traced operands; conc stays inf (shape source only
        # — the rate curve never reads it).  tk1 = decode_step_s(1) is
        # precomputed here so the trace and the host share one rounding.
        tkw = np.zeros(E)
        tkv = np.zeros(E)
        tkf = np.zeros(E)
        tkc = np.ones(E)
        tk1 = np.ones(E)
        for j, e in enumerate(engines):
            m = work_model.engines.get(e)
            if m is None:
                raise ValueError(
                    f"work_model has no token model for engine {e!r}: the "
                    "token calendar needs every trie engine's decode curve")
            tkw[j] = float(m.t_weights_s)
            tkv[j] = float(m.t_kv_s)
            tkf[j] = float(m.t_flop_s)
            tkc[j] = float(m.kv_capacity)
            tk1[j] = max(float(m.t_weights_s) + float(m.t_kv_s),
                         float(m.t_flop_s))
            ms[j] = float(work_model.mean_service_s.get(e, 1.0))
            hasm[j] = True
    elif ps:
        from repro.serving.loadsim import EngineLoadModel, FleetLoadModel
        if not isinstance(fleet_load, FleetLoadModel) or not all(
                isinstance(m, EngineLoadModel)
                for m in fleet_load.engines.values()):
            raise NotImplementedError(
                "compiled event engine supports FleetLoadModel with "
                "EngineLoadModel entries; use the host loop for duck-typed "
                "load models")
        for j, e in enumerate(engines):
            m = fleet_load.engines.get(e)
            if m is not None:
                conc[j] = float(m.concurrency)
                ms[j] = float(fleet_load.mean_service_s.get(e, 1.0))
                hasm[j] = True

    order = np.argsort(arrivals, kind="stable")
    seq_of = np.empty(B, dtype=np.int64)
    seq_of[order] = np.arange(B)
    members = np.full((K, B), -1, dtype=np.int32)
    cls_ord = cls_idx[order].astype(np.int32)
    for k in range(K):
        mem_k = order[cls_ord == k]
        members[k, :mem_k.size] = mem_k

    # only (depth, model) pairs some trie node can dispatch get probed
    probe = np.zeros((max_depth + 1, M), dtype=bool)
    node_depth = trie.depth.astype(np.int64)
    has_child = trie.child >= 0  # (n_nodes, M)
    np.logical_or.at(probe, node_depth, has_child)
    tab_s, tab_c, tab_l, row = _tabulate_executor(
        executor, requests, probe, t_start, work_model=work_model,
        engines=engines,
        engine_of_model=np.asarray(td.engine_of_model, dtype=np.int64))
    best_acc, min_cost = _subtree_reductions(trie, ann, term_mask)

    n_shards = 1 if devices is None else int(devices)
    if n_shards < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if n_shards > 1:
        from repro.dist.sharding import lane_mesh
        lane_mesh(n_shards)  # availability check: clear error + CPU recipe

    sketch = QuantileSketch.log_spaced()
    cfg = _EngineConfig(
        capacity=C, n_classes=K, n_engines=E, n_models=M,
        max_depth=max_depth, priorities=priorities, preempt=bool(preempt),
        ps=ps, load_aware=load_aware, tokens=tokens,
        deadline_sheds=deadline_sheds,
        pol=tpol, kind=obj.kind, kind_dg="min_cost",
        variant=_resolve_variant(plan_variant), n_bins=sketch.n_bins,
        n_shards=n_shards, explore=explore_model is not None,
        fault_outages=fault_outages, fault_failures=fault_failures,
        max_retries=int(faults.max_retries) if faults is not None else 0,
        # outage victims can stack past C across repeated outages, so the
        # paused buffer is sized B under fault injection (shapes already
        # carry B-sized columns — no retrace cost)
        paused_cap=B if fault_outages else (C if priorities else 0))
    step = _build_step(cfg)

    from jax.experimental import enable_x64

    with enable_x64():
        import jax.numpy as jnp

        dg_obj = Objective("min_cost", acc_floor=-1.0,
                           cost_cap=obj.cost_cap, lat_cap=plan_obj.lat_cap)
        cn = {
            "td": td,
            "sc": objective_scalars(plan_obj),
            "scdg": objective_scalars(dg_obj),
            "arr": jnp.asarray(arrivals),
            "arrs": jnp.asarray(arrivals[order]),
            "cap": jnp.asarray(cap_req),
            "wreq": jnp.asarray(weight_req),
            "shift": jnp.asarray(lat_shift),
            "seq": jnp.asarray(seq_of),
            "cls": jnp.asarray(cls_idx.astype(np.int32)),
            "clsord": jnp.asarray(cls_ord),
            "members": jnp.asarray(members),
            "wcls": jnp.asarray(w_cls),
            "child": jnp.asarray(trie.child.astype(np.int32)),
            "depth": jnp.asarray(trie.depth.astype(np.int32)),
            "eom": jnp.asarray(
                np.asarray(td.engine_of_model).astype(np.int32)),
            "row": jnp.asarray(row),
            "tabs": jnp.asarray(tab_s),
            "tabc": jnp.asarray(tab_c),
            "tabl": jnp.asarray(tab_l),
            "conc": jnp.asarray(conc),
            "ms": jnp.asarray(ms),
            "hasm": jnp.asarray(hasm),
            "bacc": jnp.asarray(best_acc),
            "mcost": jnp.asarray(min_cost),
            "edges": jnp.asarray(sketch.edges),
        }
        if tokens:
            # added only under the token calendar so legacy configs keep
            # their exact operand pytree (and compiled-program cache keys)
            cn["tkw"] = jnp.asarray(tkw)
            cn["tkv"] = jnp.asarray(tkv)
            cn["tkf"] = jnp.asarray(tkf)
            cn["tkc"] = jnp.asarray(tkc)
            cn["tk1"] = jnp.asarray(tk1)
        if explore_model is not None:
            cn["xpm"] = jnp.asarray(explore_model)
        if fault_outages:
            # transition columns, padded with one sentinel row so the
            # traced cursor clip reads (inf, engine 0, up) past the end
            fev = faults.events(engines)
            cn["ftt"] = jnp.asarray(
                np.array([t for t, _, _ in fev] + [np.inf]))
            cn["fte"] = jnp.asarray(
                np.array([ei for _, ei, _ in fev] + [0], dtype=np.int32))
            cn["ftu"] = jnp.asarray(
                np.array([up for _, _, up in fev] + [True], dtype=bool))
        if fault_failures:
            cn["fdr"] = jnp.asarray(faults.failure_draws(B, max_depth))
            cn["fbo"] = jnp.asarray(
                np.array([faults.backoff(a)
                          for a in range(int(faults.max_retries) + 1)]))
        st = _init_state(jnp, cfg, B, arrivals[order])

        arrs = arrivals[order]
        chunk = max(int(epoch), 1)
        pos = 0
        si = 0
        while True:
            pos2 = min(pos + chunk, B)
            t_arr_hi = np.inf if pos2 >= B else float(arrs[pos2 - 1])
            if si < len(swaps) and swaps[si][0] < t_arr_hi:
                # annotation-version swap: run the current program up to
                # the swap time (events at t <= t_swap stay under the old
                # annotations — same rule as the host loop), then
                # substitute the new TrieDevice operand.  t_hi and the
                # annotation columns are traced operands, so the swap
                # compiles ZERO new programs.
                st = step(st, cn, float(swaps[si][0]))
                cn = {**cn, "td": swaps[si][1]}
                si += 1
                continue
            st = step(st, cn, t_arr_hi)
            pos = pos2
            if pos >= B:
                # arrivals exhausted: one final unbounded epoch drains
                # every remaining completion/deadline event
                break
        stats.annotation_swaps = si
        n_done = int(st["don"])
        if n_done != B:
            raise RuntimeError(
                f"compiled event loop stalled with work outstanding "
                f"({n_done}/{B} requests terminal)")

        stats.events = int(st["ev"])
        stats.replans = int(st["rp"])
        stats.admitted = int(st["adm"])
        stats.rejected = int(st["rej"])
        stats.shed = int(st["shd"])
        stats.downgraded = int(st["dgc"])
        stats.preemptions = int(st["pre"])
        stats.resumed = int(st["res"])
        stats.explored = int(st["xpc"])
        if fault_outages:
            stats.engine_outages = int(st["foc"])
            stats.engine_recoveries = int(st["frc"])
            stats.checkpointed = int(st["fck"])
        if fault_failures:
            stats.stage_failures = int(st["fsc"])
            stats.fault_retries = int(st["frt"])
        if fault_outages or fault_failures:
            stats.failed = int(st["ffc"])
        stats.peak_occupancy = {
            e: int(v) for e, v in zip(engines, np.asarray(st["po"]))}
        sketch.merge_counts(np.asarray(st["hist"]), edges=sketch.edges)
        if stream:
            # constant-memory path: per-request columns stay on device and
            # are never materialized as host-side python lists; the summary
            # is O(1) scalars + the fixed-size quantile histogram (carried
            # under "sketch" so shard drains merge exactly)
            summary = {
                "n_requests": B,
                "events": stats.events,
                "replans": stats.replans,
                "served": B - stats.rejected - stats.shed - stats.failed,
                "succeeded": int(jnp.sum(st["rsc"])),
                "rejected": stats.rejected,
                "shed": stats.shed,
                "failed": stats.failed,
                "slo_violations": int(st["slo"]),
                "latency": _wf(st["lw"]),
                "cost": _wf(st["cw"]),
                "latency_p50": sketch.quantile(0.5),
                "latency_p95": sketch.quantile(0.95),
                "latency_p99": sketch.quantile(0.99),
                "sketch": sketch.state(),
            }
            stats.preempt_count = np.zeros(0, dtype=np.int64)
            stats.outcome = []
            return summary, stats

        roc = np.asarray(st["roc"])
        rsc = np.asarray(st["rsc"])
        rct = np.asarray(st["rct"])
        ru = np.asarray(st["ru"])
        stats.done_t = np.asarray(st["rdn"]).copy()
        stats.admit_t = np.asarray(st["rad"]).copy()
        stats.preempt_count = np.asarray(st["rpc"]).astype(np.int64)
        stats.outcome = [_OUTCOMES[int(o)] for o in roc]
        results = []
        for i in range(B):
            lat = float(stats.done_t[i] - stats.arrival_t[i])
            slo = bool(np.isfinite(cap_req[i])) and lat > cap_req[i] + _SLO_TOL
            mods = trie.path(int(ru[i]))
            results.append(ExecutionResult(
                success=bool(rsc[i]),
                total_cost=float(rct[i]),
                total_lat=lat,
                models=mods,
                n_stages=len(mods),
                replan_overhead_s=0.0,
                slo_violated=slo,
                outcome=stats.outcome[i],
            ))
        return results, stats


def _wf(wt) -> dict:
    """Finalize a traced Welford triple into host floats."""
    from repro.core.streaming import welford_finalize
    return welford_finalize(tuple(float(x) for x in wt))


def _empty_summary(stats: EventStats) -> dict:
    from repro.core.streaming import welford_finalize, welford_init
    z = welford_finalize(welford_init())
    return {"n_requests": 0, "events": 0, "replans": 0, "served": 0,
            "succeeded": 0, "rejected": 0, "shed": 0, "failed": 0,
            "slo_violations": 0,
            "latency": z, "cost": z, "latency_p50": float("nan"),
            "latency_p95": float("nan"), "latency_p99": float("nan"),
            "sketch": QuantileSketch.log_spaced().state()}


def _init_state(jnp, cfg: _EngineConfig, B: int, arrs_sorted: np.ndarray):
    """Device state pytree at t=0 (first event = first arrival)."""
    C, K, E = cfg.capacity, cfg.n_classes, cfg.n_engines
    P = cfg.paused_cap
    i32, i64, f64 = jnp.int32, jnp.int64, jnp.float64
    st = {
        "tn": jnp.asarray(float(arrs_sorted[0]), f64),
        "tl": jnp.asarray(0.0, f64),
        "ap": jnp.asarray(0, i64),
        "ns": jnp.asarray(0, i64),
        "wtd": jnp.asarray(False),
        "ev": jnp.asarray(0, i64), "rp": jnp.asarray(0, i64),
        "adm": jnp.asarray(0, i64), "rej": jnp.asarray(0, i64),
        "shd": jnp.asarray(0, i64), "dgc": jnp.asarray(0, i64),
        "pre": jnp.asarray(0, i64), "res": jnp.asarray(0, i64),
        "don": jnp.asarray(0, i64), "slo": jnp.asarray(0, i64),
        "po": jnp.zeros(E, i64),
        "so": jnp.full(C, -1, i32),
        "su": jnp.zeros(C, i32),
        "sec": jnp.zeros(C, f64),
        "sm": jnp.full(C, -1, i32),
        "sok": jnp.zeros(C, bool),
        "sdg": jnp.zeros(C, bool),
        "sfree": jnp.ones(C, bool),
        "snd": jnp.zeros(C, bool),
        "sddl": jnp.full(C, jnp.inf, f64),
        "je": jnp.full(C, -1, i32),
        "jsq": jnp.zeros(C, i64),
        "jtc": jnp.full(C, jnp.inf, f64),
        "jwk": jnp.zeros(C, f64),
        "jrm": jnp.full(C, jnp.inf, f64),
        "jw": jnp.ones(C, f64),
        "qh": jnp.zeros(K, i32),
        "qt": jnp.zeros(K, i32),
        "roc": jnp.full(B, _OC_SERVED, i32),
        "rsc": jnp.zeros(B, bool),
        "rct": jnp.zeros(B, f64),
        "rdn": jnp.zeros(B, f64),
        "rad": jnp.zeros(B, f64),
        "ru": jnp.zeros(B, i32),
        "rpc": jnp.zeros(B, i32),
        "lw": (jnp.asarray(0.0, f64), jnp.asarray(0.0, f64),
               jnp.asarray(0.0, f64)),
        "cw": (jnp.asarray(0.0, f64), jnp.asarray(0.0, f64),
               jnp.asarray(0.0, f64)),
        "hist": jnp.zeros(cfg.n_bins, i64),
        "xpc": jnp.asarray(0, i64),
    }
    if cfg.priorities or cfg.fault_outages:
        st.update({
            "pb": jnp.full((K, P), -1, i32),
            "pn": jnp.zeros(K, i32),
            "rpu": jnp.zeros(B, i32),
            "rpm": jnp.zeros(B, i32),
            "rpok": jnp.zeros(B, bool),
            "rprm": jnp.zeros(B, f64),
            "rpec": jnp.zeros(B, f64),
            "rpdg": jnp.zeros(B, bool),
            "rpp": jnp.zeros(B, bool),
        })
    if cfg.fault_outages or cfg.fault_failures:
        st.update({
            "rfl": jnp.zeros(B, bool),
            "rpat": jnp.zeros((B, cfg.max_depth), i64),
            "ffc": jnp.asarray(0, i64),
        })
    if cfg.fault_outages:
        st.update({
            "av": jnp.ones(E, bool),
            "fi": jnp.asarray(0, i32),
            "foc": jnp.asarray(0, i64),
            "frc": jnp.asarray(0, i64),
            "fck": jnp.asarray(0, i64),
        })
    if cfg.fault_failures:
        st.update({
            "srt": jnp.full(C, jnp.inf, f64),
            "fsc": jnp.asarray(0, i64),
            "frt": jnp.asarray(0, i64),
        })
    if cfg.pol.wants_forecast:
        st["dead"] = jnp.zeros(B, bool)
    return st


def merge_stream_summaries(a: dict, b: dict) -> dict:
    """Fold two streaming summaries (e.g. per-shard drains of a sharded
    replay) into one — the merge is EXACT: counters add, Welford moments
    combine via Chan's parallel update, and the quantile sketches (each
    summary carries its histogram under ``"sketch"``) merge bin-by-bin
    before the p50/p95/p99 fields are recomputed from the merged counts.
    Sketch merging validates the bin edges bitwise and raises
    ``ValueError`` when the two summaries were accumulated over different
    binnings (or when only one side carries a sketch) — a silent merge of
    incompatible histograms would corrupt every reported quantile."""
    out = dict(a)
    for key in ("n_requests", "events", "replans", "served", "succeeded",
                "rejected", "shed", "failed", "slo_violations"):
        out[key] = a[key] + b[key]
    for key in ("latency", "cost"):
        wa = (a[key]["count"], a[key]["mean"], a[key]["var"] * a[key]["count"])
        wb = (b[key]["count"], b[key]["mean"], b[key]["var"] * b[key]["count"])
        c, m, m2 = welford_merge(wa, wb)
        var = m2 / c if c > 0 else 0.0
        out[key] = {"count": c, "mean": m, "var": var,
                    "std": float(np.sqrt(max(var, 0.0)))}
    has_a, has_b = "sketch" in a, "sketch" in b
    if has_a != has_b:
        raise ValueError(
            "cannot merge stream summaries: only one side carries a "
            "quantile sketch — quantiles are not mergeable from the "
            "finalized p50/p95/p99 fields alone")
    if has_a:
        sk = QuantileSketch.from_state(a["sketch"])
        sk.merge(QuantileSketch.from_state(b["sketch"]))  # validates edges
        out["sketch"] = sk.state()
        out["latency_p50"] = sk.quantile(0.5)
        out["latency_p95"] = sk.quantile(0.95)
        out["latency_p99"] = sk.quantile(0.99)
    return out
