"""Trie annotation estimators (paper §4.2, §5.3, Appendix A).

Six estimators of the per-path expected accuracy (column means of the
request-path table A), in the paper's order:

1. ``direct_average``   — raw mean of direct cascade observations.  Badly
   pessimistic for deep paths: those columns are observed only on the hard
   subpopulation where every earlier stage failed (MNAR, eq. (3)).
2. ``prefix_avg``       — prefix-success closure (subtree fill-in) then
   column average.  Optimistic: fill-in injects the easy successes but the
   observed failures still come from the hard subpopulation.
3. ``prefix_impute``    — fill-in, then low-rank soft-impute matrix
   completion, then column means.
4. ``prefix_gbt``       — fill-in, then gradient-boosted stumps on
   hand-designed path/observation features (in-repo replacement for the
   paper's XGBoost baseline).
5. ``vinelm_lite``      — cascade decomposition (eq. (7)-(9)): treat direct
   column means as *conditional* accuracies and reconstruct path means via
   mu(u) = mu(parent) + (1 - mu(parent)) * q(last | prefix fails).
6. ``vinelm``           — cascade decomposition + rank-1 SVD smoothing of
   the sparse deep conditional blocks (§A.4).

All return a vector ``mu`` over trie nodes with ``mu[0] = 0``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.profiler import ProfileResult
from repro.core.streaming import welford_merge, welford_update
from repro.core.trie import Trie, TrieAnnotations


# ----------------------------------------------------------------------
# shared helpers
# ----------------------------------------------------------------------
def _col_stats(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Column means & counts of an int8 matrix with -1 = missing."""
    mask = values >= 0
    cnt = mask.sum(axis=0)
    s = np.where(mask, values, 0).sum(axis=0)
    mean = np.divide(s, np.maximum(cnt, 1), dtype=np.float64)
    return mean, cnt


def _fallback_by_depth_model(
    trie: Trie, est: np.ndarray, have: np.ndarray
) -> np.ndarray:
    """Fill missing per-node values with (depth, model)-group means, then
    depth means, then the global mean."""
    out = est.copy()
    depth = trie.depth
    model = trie.model
    global_mean = est[have].mean() if have.any() else 0.5
    for d in np.unique(depth[depth > 0]):
        sel_d = depth == d
        d_have = sel_d & have
        d_mean = est[d_have].mean() if d_have.any() else global_mean
        for m in np.unique(model[sel_d]):
            sel = sel_d & (model == m)
            g_have = sel & have
            g_mean = est[g_have].mean() if g_have.any() else d_mean
            out[sel & ~have] = g_mean
    return out


def _monotone_floor(trie: Trie, mu: np.ndarray) -> np.ndarray:
    """Clip to [0,1]; used by baselines (no monotonicity enforcement —
    the paper's baselines are biased and that is the point)."""
    return np.clip(mu, 0.0, 1.0)


# ----------------------------------------------------------------------
# 1-2: averaging estimators
# ----------------------------------------------------------------------
def direct_average(trie: Trie, profile: ProfileResult) -> np.ndarray:
    """Estimator 1: per-node mean over *observed* outcomes only, with
    depth/model fallback for unobserved nodes and a monotone floor."""
    mean, cnt = _col_stats(profile.obs)
    mu = _fallback_by_depth_model(trie, mean, cnt > 0)
    mu[0] = 0.0
    return _monotone_floor(trie, mu)


def prefix_avg(trie: Trie, profile: ProfileResult) -> np.ndarray:
    """Estimator 2: per-node mean over prefix-filled outcomes (a success
    observed at a node implies success at every ancestor), same fallback
    and monotone floor as `direct_average`."""
    mean, cnt = _col_stats(profile.observed_filled())
    mu = _fallback_by_depth_model(trie, mean, cnt > 0)
    mu[0] = 0.0
    return _monotone_floor(trie, mu)


# ----------------------------------------------------------------------
# 3: fill-in + low-rank soft-impute
# ----------------------------------------------------------------------
def _truncated_svd(X: np.ndarray, r: int, seed: int = 0):
    """Randomized truncated SVD (no scipy in this container)."""
    rng = np.random.default_rng(seed)
    n, m = X.shape
    k = min(r + 6, min(n, m))
    Omega = rng.standard_normal((m, k))
    Y = X @ Omega
    for _ in range(2):  # power iterations for accuracy
        Y = X @ (X.T @ Y)
    Q, _ = np.linalg.qr(Y)
    B = Q.T @ X
    Ub, s, Vt = np.linalg.svd(B, full_matrices=False)
    U = Q @ Ub
    return U[:, :r], s[:r], Vt[:r]


def prefix_impute(
    trie: Trie,
    profile: ProfileResult,
    *,
    rank: int = 4,
    iters: int = 15,
    ridge: float = 2.0,
) -> np.ndarray:
    """Low-rank matrix completion with row/column biases, fit on observed
    entries by alternating ridge least squares (standard recommender-style
    completion; the strongest fair version of the paper's baseline)."""
    filled = profile.observed_filled().astype(np.float64)
    mask = filled >= 0
    n_q, n = filled.shape
    rng = np.random.default_rng(0)
    g = filled[mask].mean() if mask.any() else 0.5
    br = np.zeros(n_q)
    bc = np.zeros(n)
    U = 0.01 * rng.standard_normal((n_q, rank))
    V = 0.01 * rng.standard_normal((n, rank))
    W = mask.astype(np.float64)
    Y = np.where(mask, filled, 0.0)
    for _ in range(iters):
        # biases (closed form given factors)
        resid = Y - (g + bc[None, :] + (U @ V.T)) * W
        br = (resid * W).sum(1) / (W.sum(1) + ridge)
        resid = Y - (g + br[:, None] + (U @ V.T)) * W
        bc = (resid * W).sum(0) / (W.sum(0) + ridge)
        R = Y - (g + br[:, None] + bc[None, :]) * W
        # ALS: per-row then per-col ridge solves
        for i in range(n_q):
            m = mask[i]
            if not m.any():
                continue
            Vm = V[m]
            A = Vm.T @ Vm + ridge * np.eye(rank)
            U[i] = np.linalg.solve(A, Vm.T @ R[i, m])
        for j in range(n):
            m = mask[:, j]
            if not m.any():
                continue
            Um = U[m]
            A = Um.T @ Um + ridge * np.eye(rank)
            V[j] = np.linalg.solve(A, Um.T @ R[m, j])
    X = np.clip(g + br[:, None] + bc[None, :] + U @ V.T, 0.0, 1.0)
    X = np.where(mask, filled, X)
    mu = X.mean(axis=0)
    mu[0] = 0.0
    return _monotone_floor(trie, mu)


# ----------------------------------------------------------------------
# 4: fill-in + gradient-boosted stumps (XGBoost stand-in)
# ----------------------------------------------------------------------
class _GBTStumps:
    """Tiny gradient-boosted regression stumps, squared loss."""

    def __init__(self, rounds: int = 200, lr: float = 0.08, n_thresh: int = 16):
        self.rounds, self.lr, self.n_thresh = rounds, lr, n_thresh
        self.stumps: list[tuple[int, float, float, float]] = []
        self.base = 0.0

    def fit(self, F: np.ndarray, y: np.ndarray) -> "_GBTStumps":
        self.base = float(y.mean())
        pred = np.full_like(y, self.base)
        for _ in range(self.rounds):
            resid = y - pred
            best = None  # (sse, j, t, left, right)
            for j in range(F.shape[1]):
                col = F[:, j]
                qs = np.quantile(col, np.linspace(0.05, 0.95, self.n_thresh))
                for t in np.unique(qs):
                    m = col <= t
                    if m.all() or not m.any():
                        continue
                    l, r = resid[m].mean(), resid[~m].mean()
                    sse = ((resid[m] - l) ** 2).sum() + ((resid[~m] - r) ** 2).sum()
                    if best is None or sse < best[0]:
                        best = (sse, j, float(t), float(l), float(r))
            if best is None:
                break
            _, j, t, l, r = best
            self.stumps.append((j, t, l, r))
            pred = pred + self.lr * np.where(F[:, j] <= t, l, r)
        return self

    def predict(self, F: np.ndarray) -> np.ndarray:
        pred = np.full(F.shape[0], self.base)
        for j, t, l, r in self.stumps:
            pred = pred + self.lr * np.where(F[:, j] <= t, l, r)
        return pred


def _column_features(trie: Trie, profile: ProfileResult) -> np.ndarray:
    """Hand-designed features per trie node (paper §5.3: depth, observation
    counts, column means, prefix values, sibling statistics, model power)."""
    filled = profile.observed_filled()
    fmean, fcnt = _col_stats(filled)
    dmean, dcnt = _col_stats(profile.obs)
    fmean = _fallback_by_depth_model(trie, fmean, fcnt > 0)
    n = trie.n_nodes
    par = trie.parent.copy()
    par[0] = 0
    parent_est = fmean[par]
    # model power proxy: depth-1 filled mean of the same model
    d1 = trie.nodes_at_depth(1)
    power = np.zeros(trie.n_models)
    for u in d1:
        power[trie.model[u]] = fmean[u]
    power_f = np.where(trie.model >= 0, power[np.maximum(trie.model, 0)], 0.0)
    # sibling mean
    sib = np.zeros(n)
    for u in range(n):
        kids = trie.child[u][trie.child[u] >= 0]
        if kids.size:
            sib[kids] = fmean[kids].mean()
    # observed-row hardness: mean success of the rows observed in the column
    obs = profile.obs
    row_succ = np.where(obs >= 0, obs, 0).sum(axis=1) / np.maximum(
        (obs >= 0).sum(axis=1), 1
    )
    hardness = np.zeros(n)
    for u in range(n):
        rows = obs[:, u] >= 0
        hardness[u] = row_succ[rows].mean() if rows.any() else row_succ.mean()
    F = np.stack(
        [
            trie.depth.astype(np.float64),
            dcnt.astype(np.float64),
            fcnt.astype(np.float64),
            fmean,
            parent_est,
            power_f,
            sib,
            hardness,
            np.where(dcnt > 0, dmean, -1.0),
        ],
        axis=1,
    )
    return F


def prefix_gbt(trie: Trie, profile: ProfileResult, *, rounds: int = 200) -> np.ndarray:
    """Estimator 4: gradient-boosted stumps over per-node features,
    trained on the least-biased target columns available (calibration
    rows when provided, else near-fully-observed columns)."""
    F = _column_features(trie, profile)
    filled = profile.observed_filled()
    fmean, fcnt = _col_stats(filled)
    n_q = filled.shape[0]
    calib = profile.calibration_rows
    if calib is not None and len(calib) >= 8:
        # calibration rows are exhaustively profiled, so their column means
        # are unbiased (high-variance) targets across *all* depths
        sub = filled[calib]
        tgt_mean, tgt_cnt = _col_stats(sub)
        train = (tgt_cnt >= max(4, int(0.8 * len(calib)))) & (trie.depth > 0)
        targets = tgt_mean
    else:
        # no calibration: train on near-fully-observed columns, whose filled
        # means are unbiased irrespective of the MNAR pattern
        train = (fcnt >= 0.85 * n_q) & (trie.depth > 0)
        targets = fmean
    if train.sum() < 6:
        train = (fcnt >= np.quantile(fcnt[trie.depth > 0], 0.8)) & (trie.depth > 0)
        targets = fmean
    model = _GBTStumps(rounds=rounds).fit(F[train], targets[train])
    mu = model.predict(F)
    mu[0] = 0.0
    return _monotone_floor(trie, mu)


# ----------------------------------------------------------------------
# 5-6: cascade decomposition (VineLM-Lite) and + rank-1 smoothing (VineLM)
# ----------------------------------------------------------------------
def _conditional_means(trie: Trie, profile: ProfileResult):
    """Direct column means = unbiased conditional accuracies (eq. (3))."""
    return _col_stats(profile.obs)


def _compose(trie: Trie, q_hat: np.ndarray) -> np.ndarray:
    """mu(u) = mu(parent) + (1 - mu(parent)) * q_hat(u)   (eq. (7)-(9))."""
    mu = np.zeros(trie.n_nodes)
    for u in range(1, trie.n_nodes):
        p = trie.parent[u]
        mu[u] = mu[p] + (1.0 - mu[p]) * q_hat[u]
    return mu


def vinelm_lite(trie: Trie, profile: ProfileResult) -> np.ndarray:
    """Estimator 5: cascade decomposition — estimate per-node conditional
    accuracies (unbiased under MNAR prefix observation) and compose them
    down the trie (paper eq. (3), (7)-(9))."""
    q_mean, q_cnt = _conditional_means(trie, profile)
    q_hat = _fallback_by_depth_model(trie, q_mean, q_cnt > 0)
    q_hat = np.clip(q_hat, 0.0, 1.0)
    q_hat[0] = 0.0
    return _compose(trie, q_hat)


def vinelm(
    trie: Trie,
    profile: ProfileResult,
    *,
    smooth_min_obs: int = 30,
    rank: int = 1,
) -> np.ndarray:
    """Cascade decomposition with rank-1 smoothing of sparse depth blocks.

    For each depth d whose median per-column direct-observation count is
    below ``smooth_min_obs`` (paper: the depth-3 block at 5% coverage has
    ~20-80 observations per column), assemble the conditional matrix
    Q_d[prefix, model], initialize unobserved entries with column means, and
    project onto the rank-1 manifold (§A.4, eq. (10)).  Well-observed blocks
    keep their raw conditional means to avoid introducing bias.
    """
    q_mean, q_cnt = _conditional_means(trie, profile)
    q_hat = _fallback_by_depth_model(trie, q_mean, q_cnt > 0)
    q_hat = np.clip(q_hat, 0.0, 1.0)
    q_hat[0] = 0.0

    max_depth = int(trie.depth.max())
    for d in range(2, max_depth + 1):
        nodes_d = trie.nodes_at_depth(d)
        med = np.median(q_cnt[nodes_d]) if nodes_d.size else np.inf
        if med >= smooth_min_obs:
            continue
        prefixes = trie.nodes_at_depth(d - 1)
        M = trie.n_models
        pidx = {int(u): i for i, u in enumerate(prefixes)}
        Q = np.full((len(prefixes), M), np.nan)
        W = np.zeros((len(prefixes), M))
        for v in nodes_d:
            i = pidx[int(trie.parent[v])]
            m = int(trie.model[v])
            if q_cnt[v] > 0:
                Q[i, m] = q_mean[v]
                W[i, m] = q_cnt[v]
        # column-mean initialization for unobserved entries
        col = np.nanmean(np.where(np.isnan(Q), np.nan, Q), axis=0)
        col = np.where(np.isnan(col), np.nanmean(col) if not np.all(np.isnan(col)) else 0.5, col)
        Qf = np.where(np.isnan(Q), col[None, :], Q)
        # rank-r projection (paper: rank-1)
        U, s, Vt = np.linalg.svd(Qf, full_matrices=False)
        Qs = (U[:, :rank] * s[:rank]) @ Vt[:rank]
        Qs = np.clip(Qs, 0.0, 1.0)
        for v in nodes_d:
            i = pidx[int(trie.parent[v])]
            q_hat[v] = Qs[i, int(trie.model[v])]
    return _compose(trie, q_hat)


# ----------------------------------------------------------------------
# registry + full annotation (accuracy + reconstructed cost & latency)
# ----------------------------------------------------------------------
ESTIMATORS = {
    "direct_average": direct_average,
    "prefix_avg": prefix_avg,
    "prefix_impute": prefix_impute,
    "prefix_gbt": prefix_gbt,
    "vinelm_lite": vinelm_lite,
    "vinelm": vinelm,
}


def estimate_accuracy(name: str, trie: Trie, profile: ProfileResult, **kw) -> np.ndarray:
    """Dispatch to a named estimator in `ESTIMATORS` (paper §5 table)."""
    return ESTIMATORS[name](trie, profile, **kw)


def _stage_means_filled(trie: Trie, profile: ProfileResult):
    """(D, M) cost/latency means with model-mean then global fallbacks."""
    cm, lm = profile.stage_cost_mean(), profile.stage_lat_mean()
    cnt = profile.stage_count
    out_c, out_l = cm.copy(), lm.copy()
    have = cnt > 0
    for arr_src, arr_out in ((cm, out_c), (lm, out_l)):
        g = arr_src[have].mean() if have.any() else 0.0
        for m in range(arr_src.shape[1]):
            col_have = have[:, m]
            col_mean = arr_src[col_have, m].mean() if col_have.any() else g
            arr_out[~col_have, m] = col_mean
    return out_c, out_l


def annotate(
    trie: Trie, profile: ProfileResult, name: str = "vinelm", **kw
) -> TrieAnnotations:
    """Full trie annotation from a sparse profile.

    Accuracy via the chosen estimator; cost reconstructed as
    C(u) = C(parent) + (1 - mu(parent)) * c(d, m)   (early-stop discounted);
    latency as T(u) = T(parent) + tau(d, m)         (conditional, undiscounted)
    — the paper's §3.3 semantics, with (d, m) means from profiler telemetry.
    """
    mu = estimate_accuracy(name, trie, profile, **kw)
    cmean, lmean = _stage_means_filled(trie, profile)
    n = trie.n_nodes
    cost = np.zeros(n)
    lat = np.zeros(n)
    tpl = trie.template
    for u in range(1, n):
        p = int(trie.parent[u])
        d = int(trie.depth[u]) - 1
        m = int(trie.model[u])
        tc, tl = tpl.tool_cost_latency(d)
        cost[u] = cost[p] + (1.0 - mu[p]) * (cmean[d, m] + tc)
        lat[u] = lat[p] + lmean[d, m] + tl
    return TrieAnnotations(acc=mu, cost=cost, lat=lat)


# ----------------------------------------------------------------------
# online estimator refresh (ISSUE 8): streaming posteriors over the
# per-(invocation depth, model) stage statistics, seeded from the offline
# cascade profile as priors, with exponential forgetting so drift
# (engines slowing down, model-quality regressions) is tracked instead of
# averaged away.  `TrieAnnotator` re-derives the trie annotation tables
# from the current posteriors and publishes them as versioned
# `controller_jax.TrieDevice` columns that swap into the running control
# plane with zero new compiled programs.
# ----------------------------------------------------------------------
@dataclasses.dataclass
class BetaPosterior:
    """Streaming Beta posterior over a stage success probability.

    The offline profile contributes the prior (``prior`` mean backed by
    ``strength`` pseudo-observations); online executions accumulate into
    the decayed sufficient statistics ``weight`` (observation count) and
    ``successes``.  `mean` is written as *prior plus correction* —
    ``prior + (successes - weight*prior) / (strength + weight)`` — which
    is algebraically the Beta posterior mean
    ``(strength*prior + successes) / (strength + weight)`` but evaluates
    to the offline prior BITWISE when there are zero online observations
    (the correction term is exactly ±0.0), so an idle refresh loop can
    never perturb the offline annotations.
    """

    prior: float
    strength: float
    weight: float = 0.0
    successes: float = 0.0

    def observe(self, success: bool, weight: float = 1.0) -> None:
        """Fold one realized stage outcome into the posterior."""
        self.weight += weight
        if success:
            self.successes += weight

    def decay(self, gamma: float) -> None:
        """Exponential forgetting: scale the online evidence by ``gamma``
        in [0, 1].  The posterior mean moves monotonically toward the
        offline prior as ``gamma`` shrinks (the evidence weight
        ``gamma*weight / (strength + gamma*weight)`` is increasing in
        ``gamma``)."""
        if not 0.0 <= gamma <= 1.0:
            raise ValueError(f"decay factor must be in [0, 1], got {gamma}")
        self.weight *= gamma
        self.successes *= gamma

    def mean(self) -> float:
        """Posterior mean; exactly ``prior`` at zero observations."""
        return self.prior + (self.successes - self.weight * self.prior) / (
            self.strength + self.weight)

    def ucb(self, c: float = 1.0) -> float:
        """Optimistic upper bound ``mean + c / sqrt(strength + weight)``
        for UCB-style exploration scoring."""
        return self.mean() + c / np.sqrt(self.strength + self.weight)

    def merge(self, other: "BetaPosterior") -> "BetaPosterior":
        """Combine evidence from two streams over the same prior.  Sums
        of sufficient statistics, so merge is exactly commutative."""
        if (other.prior, other.strength) != (self.prior, self.strength):
            raise ValueError("cannot merge BetaPosteriors with different "
                             "priors")
        return BetaPosterior(self.prior, self.strength,
                             self.weight + other.weight,
                             self.successes + other.successes)

    def state(self) -> dict:
        """JSON-able snapshot; `from_state` round-trips it exactly."""
        return {"prior": self.prior, "strength": self.strength,
                "weight": self.weight, "successes": self.successes}

    @classmethod
    def from_state(cls, state: dict) -> "BetaPosterior":
        """Rebuild from a `state()` snapshot."""
        return cls(**state)


@dataclasses.dataclass
class GaussianPosterior:
    """Streaming posterior over a stage cost/latency mean.

    Online evidence lives in a `repro.core.streaming` Welford triple
    ``(count, mean, M2)``; `decay` scales ``count`` and ``M2`` (standard
    exponential-forgetting Welford), and `mean` shrinks the evidence mean
    toward the offline prior by ``count / (strength + count)`` — the
    normal-inverse-gamma posterior mean under a prior worth ``strength``
    observations.  Like `BetaPosterior`, the prior-plus-correction form
    makes the zero-observation posterior bitwise equal to the prior.
    """

    prior: float
    strength: float
    welford: tuple = (0.0, 0.0, 0.0)

    def observe(self, x: float) -> None:
        """Fold one realized value into the Welford triple."""
        self.welford = welford_update(self.welford, float(x))

    def decay(self, gamma: float) -> None:
        """Exponential forgetting: scale the evidence count and spread."""
        if not 0.0 <= gamma <= 1.0:
            raise ValueError(f"decay factor must be in [0, 1], got {gamma}")
        n, m, m2 = self.welford
        self.welford = (n * gamma, m, m2 * gamma)

    def mean(self) -> float:
        """Posterior mean; exactly ``prior`` at zero observations."""
        n, m, _ = self.welford
        return self.prior + n * (m - self.prior) / (self.strength + n)

    def merge(self, other: "GaussianPosterior") -> "GaussianPosterior":
        """Combine evidence via Chan's parallel Welford merge.  The two
        operands are put in canonical order first, so merge is exactly
        commutative (Chan's mean update is not symmetric in floats)."""
        if (other.prior, other.strength) != (self.prior, self.strength):
            raise ValueError("cannot merge GaussianPosteriors with "
                             "different priors")
        a, b = self.welford, other.welford
        if tuple(b) < tuple(a):
            a, b = b, a
        return GaussianPosterior(self.prior, self.strength,
                                 tuple(welford_merge(a, b)))

    def state(self) -> dict:
        """JSON-able snapshot; `from_state` round-trips it exactly."""
        return {"prior": self.prior, "strength": self.strength,
                "welford": list(self.welford)}

    @classmethod
    def from_state(cls, state: dict) -> "GaussianPosterior":
        """Rebuild from a `state()` snapshot."""
        return cls(state["prior"], state["strength"],
                   tuple(state["welford"]))


class OnlineEstimators:
    """Per-(invocation depth, model) streaming posteriors for stage
    accuracy, cost, and latency.

    The container the serving loop feeds realized executions into
    (`observe`) and the `TrieAnnotator` reads tables out of.  Seed it
    from an offline cascade profile (`from_profile`) so the posteriors
    start at the profiler's estimates with evidence-proportional
    strength, or from explicit prior tables (`from_tables`).
    """

    def __init__(self, acc, cost, lat):
        self.acc = acc      # (D, M) nested lists of BetaPosterior
        self.cost = cost    # (D, M) nested lists of GaussianPosterior
        self.lat = lat      # (D, M) nested lists of GaussianPosterior
        # per-token latency posteriors (token work model, ISSUE 10):
        # created lazily on the first `observe(..., tokens=)` so legacy
        # scalar-work runs carry no extra state and their snapshots /
        # merges stay bitwise identical
        self.lat_tok = None  # (D, M) nested lists of GaussianPosterior
        self.observations = 0

    def _ensure_lat_tok(self) -> None:
        if self.lat_tok is None:
            D, M = self.shape
            self.lat_tok = [[GaussianPosterior(0.0, 1.0)
                             for _ in range(M)] for _ in range(D)]

    @property
    def shape(self) -> tuple[int, int]:
        """(max invocation depth, model count) of the posterior tables."""
        return (len(self.acc), len(self.acc[0]) if self.acc else 0)

    @classmethod
    def from_tables(cls, acc_prior: np.ndarray, cost_prior: np.ndarray,
                    lat_prior: np.ndarray, *,
                    strength=4.0) -> "OnlineEstimators":
        """Build from explicit (D, M) prior-mean tables.  ``strength``
        is scalar or a (D, M) per-cell pseudo-observation count."""
        acc_prior = np.asarray(acc_prior, dtype=np.float64)
        D, M = acc_prior.shape
        s = np.broadcast_to(np.asarray(strength, dtype=np.float64), (D, M))
        acc = [[BetaPosterior(float(acc_prior[d, m]), float(s[d, m]))
                for m in range(M)] for d in range(D)]
        cost = [[GaussianPosterior(float(cost_prior[d, m]), float(s[d, m]))
                 for m in range(M)] for d in range(D)]
        lat = [[GaussianPosterior(float(lat_prior[d, m]), float(s[d, m]))
                for m in range(M)] for d in range(D)]
        return cls(acc, cost, lat)

    @classmethod
    def from_profile(cls, trie: Trie, profile: ProfileResult, *,
                     prior_strength: float = 4.0,
                     count_weight: float = 1.0) -> "OnlineEstimators":
        """Seed the posteriors from an offline cascade profile: accuracy
        priors are the profile's per-(depth, model) conditional success
        stats (`ProfileResult.stage_success_stats`), cost/latency priors
        the filled stage means, each backed by ``prior_strength`` plus
        ``count_weight`` times the profile's actual per-cell observation
        count.  Lower ``count_weight`` (0 = flat ``prior_strength``
        everywhere) to keep a heavily-profiled prior from drowning out
        online evidence — the responsiveness knob drift-tracking
        deployments (`benchmarks/drift.py`) turn down."""
        smean, scnt = profile.stage_success_stats(trie)
        cmean, lmean = _stage_means_filled(trie, profile)
        cnt = profile.stage_count.astype(np.float64)
        acc = cls.from_tables(smean, cmean, lmean,
                              strength=prior_strength + count_weight * scnt)
        # cost/lat strength follows the telemetry count, not the outcome
        # observation count (checkpoint reuse makes them differ)
        strength = prior_strength + count_weight * cnt
        D, M = smean.shape
        for d in range(D):
            for m in range(M):
                acc.cost[d][m].strength = float(strength[d, m])
                acc.lat[d][m].strength = float(strength[d, m])
        return acc

    def observe(self, depth: int, model: int, success: bool,
                cost: float, lat: float, tokens: float | None = None) -> None:
        """Fold one realized stage execution into all three posteriors.

        ``tokens`` (token work model) additionally folds ``lat /
        tokens`` — seconds of unloaded service per token — into the
        per-token latency posterior, so drift refresh under
        ``work_model="tokens"`` distinguishes throughput drift (the
        engine got slower per token) from stage-size drift (stages got
        longer).  The stage-latency posterior is fed either way, so the
        `lat_table` the annotator publishes is unaffected."""
        self.acc[depth][model].observe(bool(success))
        self.cost[depth][model].observe(float(cost))
        self.lat[depth][model].observe(float(lat))
        if tokens is not None and tokens > 0.0:
            self._ensure_lat_tok()
            self.lat_tok[depth][model].observe(float(lat) / float(tokens))
        self.observations += 1

    def decay_all(self, gamma: float) -> None:
        """Apply exponential forgetting to every posterior cell."""
        tables = (self.acc, self.cost, self.lat) if self.lat_tok is None \
            else (self.acc, self.cost, self.lat, self.lat_tok)
        for table in tables:
            for row in table:
                for p in row:
                    p.decay(gamma)

    def merge(self, other: "OnlineEstimators") -> "OnlineEstimators":
        """Cell-wise posterior merge (e.g. shard-local evidence streams);
        commutative exactly, like the underlying posterior merges."""
        if self.shape != other.shape:
            raise ValueError(f"shape mismatch: {self.shape} vs "
                             f"{other.shape}")
        D, M = self.shape
        out = OnlineEstimators(
            [[self.acc[d][m].merge(other.acc[d][m]) for m in range(M)]
             for d in range(D)],
            [[self.cost[d][m].merge(other.cost[d][m]) for m in range(M)]
             for d in range(D)],
            [[self.lat[d][m].merge(other.lat[d][m]) for m in range(M)]
             for d in range(D)])
        if self.lat_tok is not None or other.lat_tok is not None:
            a, b = self, other
            if a.lat_tok is None or b.lat_tok is None:
                src = a.lat_tok if a.lat_tok is not None else b.lat_tok
                out.lat_tok = [[dataclasses.replace(p) for p in row]
                               for row in src]
            else:
                out.lat_tok = [[a.lat_tok[d][m].merge(b.lat_tok[d][m])
                                for m in range(M)] for d in range(D)]
        out.observations = self.observations + other.observations
        return out

    def q_table(self) -> np.ndarray:
        """(D, M) posterior conditional-accuracy means, clipped to
        [0, 1]."""
        return np.clip([[p.mean() for p in row] for row in self.acc],
                       0.0, 1.0)

    def cost_table(self) -> np.ndarray:
        """(D, M) posterior stage-cost means, floored at 0."""
        return np.maximum([[p.mean() for p in row] for row in self.cost],
                          0.0)

    def lat_table(self) -> np.ndarray:
        """(D, M) posterior stage-latency means, floored at 0."""
        return np.maximum([[p.mean() for p in row] for row in self.lat],
                          0.0)

    def lat_per_token_table(self) -> np.ndarray | None:
        """(D, M) posterior seconds-per-token means, floored at 0 — or
        None when no token-mode observation ever arrived."""
        if self.lat_tok is None:
            return None
        return np.maximum([[p.mean() for p in row] for row in self.lat_tok],
                          0.0)

    def state(self) -> dict:
        """JSON-able snapshot of every posterior cell; `from_state`
        round-trips it exactly.  The per-token table appears only when
        token-mode observations exist, so legacy snapshots are
        byte-identical to pre-token versions."""
        out = {
            "observations": self.observations,
            "acc": [[p.state() for p in row] for row in self.acc],
            "cost": [[p.state() for p in row] for row in self.cost],
            "lat": [[p.state() for p in row] for row in self.lat],
        }
        if self.lat_tok is not None:
            out["lat_tok"] = [[p.state() for p in row]
                              for row in self.lat_tok]
        return out

    @classmethod
    def from_state(cls, state: dict) -> "OnlineEstimators":
        """Rebuild from a `state()` snapshot."""
        out = cls(
            [[BetaPosterior.from_state(s) for s in row]
             for row in state["acc"]],
            [[GaussianPosterior.from_state(s) for s in row]
             for row in state["cost"]],
            [[GaussianPosterior.from_state(s) for s in row]
             for row in state["lat"]])
        if "lat_tok" in state:
            out.lat_tok = [[GaussianPosterior.from_state(s) for s in row]
                           for row in state["lat_tok"]]
        out.observations = state["observations"]
        return out


class TrieAnnotator:
    """Re-derives the trie annotation tables from the current posteriors
    and publishes them as **versioned** device tables.

    `annotations` composes the posterior conditional accuracies down the
    trie (eq. (7)-(9), same recursion as `annotate`) and rebuilds the
    cost/latency path sums from the posterior stage means.  `publish`
    wraps the result in a fresh `controller_jax.TrieDevice` with a
    bumped ``version`` and *supersedes* the previous one: the old
    device's annotation buffers are donated (deleted on device), so any
    stale reader fails loudly through `TrieDevice.check_live` instead of
    silently planning on dead annotations.  Every published device has
    identical array shapes/dtypes, so swapping it into a resident
    planner or the compiled event engine reuses every compiled program
    (the zero-retrace pins in tests/test_golden.py hold this).
    """

    def __init__(self, trie: Trie, estimators: OnlineEstimators,
                 restrict_nodes: np.ndarray | None = None):
        if estimators.shape != (trie.template.max_depth,
                                trie.template.n_models):
            raise ValueError(
                f"estimator table shape {estimators.shape} does not match "
                f"the trie's (max_depth, n_models) = "
                f"({trie.template.max_depth}, {trie.template.n_models})")
        self.trie = trie
        self.estimators = estimators
        self.restrict_nodes = restrict_nodes
        self.version = 0
        self.current = None
        self.current_ann = None

    def annotations(self) -> TrieAnnotations:
        """Current posterior-derived trie annotations (same §3.3 path
        recursion as `annotate`, with posterior stage means)."""
        trie = self.trie
        q = self.estimators.q_table()
        cmean = self.estimators.cost_table()
        lmean = self.estimators.lat_table()
        n = trie.n_nodes
        q_hat = np.zeros(n)
        for u in range(1, n):
            q_hat[u] = q[int(trie.depth[u]) - 1, int(trie.model[u])]
        mu = _compose(trie, q_hat)
        cost = np.zeros(n)
        lat = np.zeros(n)
        tpl = trie.template
        for u in range(1, n):
            p = int(trie.parent[u])
            d = int(trie.depth[u]) - 1
            m = int(trie.model[u])
            tc, tl = tpl.tool_cost_latency(d)
            cost[u] = cost[p] + (1.0 - mu[p]) * (cmean[d, m] + tc)
            lat[u] = lat[p] + lmean[d, m] + tl
        return TrieAnnotations(acc=mu, cost=cost, lat=lat)

    def publish(self):
        """Build a new versioned `TrieDevice` from the current posteriors
        and donate the superseded version's annotation buffers.  Returns
        the new device; feed it to `ResidentPlanner.swap_device` (host)
        or the compiled engine's annotation schedule.  The float64
        annotations the device was built from stay readable as
        ``self.current_ann`` (host-side consumers like the downgrade
        re-router need them alongside the float32 device columns)."""
        from repro.core.controller_jax import TrieDevice

        ann = self.annotations()
        td = TrieDevice.build(self.trie, ann, self.restrict_nodes)
        self.version += 1
        td.version = self.version
        if self.current is not None:
            self.current.supersede(self.version)
        self.current = td
        self.current_ann = ann
        return td


@dataclasses.dataclass
class RefreshConfig:
    """How the event loop drives the online estimator refresh:
    ``estimators`` accumulate realized executions, and every
    ``interval`` virtual seconds the loop decays them by ``decay`` and
    publishes a re-annotated `TrieDevice` (provided at least
    ``min_observations`` new executions arrived since the last
    publish)."""

    estimators: OnlineEstimators
    interval: float = 4.0
    decay: float = 1.0
    min_observations: int = 1
