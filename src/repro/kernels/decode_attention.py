"""Single-token decode attention (flash-decode style) as a Pallas kernel.

Decode is memory-bound: the KV cache (B, KV, S, D) streams through VMEM
once while a single query token per sequence attends to it.  The kernel
walks K-blocks sequentially with an online-softmax carry; the valid cache
length (and optional sliding window) is masked per block, so one compiled
kernel serves any fill level.

The grid is (B, KV, nK): each program handles all G = H/KV query heads of
one kv head at once — the (G, D) query tile multiplies (D, block_k) key
tiles on the MXU, which both amortizes the KV stream across the group and
keeps the matmul shapes hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, window, block_k, n_kblocks,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)       # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)       # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (G, bk)
    L = len_ref[0]                             # () valid cache length
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < L
    if window > 0:
        mask &= kpos >= L - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_kblocks - 1)
    def _done():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,        # (B, H, D)
    k_cache: jnp.ndarray,  # (B, KV, S, D)
    v_cache: jnp.ndarray,  # (B, KV, S, D)
    cache_len: jnp.ndarray,  # (B,) int32
    *,
    window: int = 0,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_k = min(block_k, S)
    assert S % block_k == 0
    n_k = S // block_k
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window,
        block_k=block_k, n_kblocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, qg, k_cache.reshape(B, KV, S, D), v_cache.reshape(B, KV, S, D))
    return out.reshape(B, H, D)
