"""Jit'd kernel wrappers with XLA fallback and recompute-based gradients.

Each op dispatches on ``use_pallas``:
- True  -> the Pallas TPU kernel (``interpret=True`` on CPU, compiled on TPU);
- False -> the pure-jnp reference (`ref.py`) — the path the CPU dry-run
  lowers, and the oracle tests compare against.

Backward passes use `jax.custom_vjp` with the reference implementation
recomputed in the backward (standard flash-attention remat pattern): the
forward enjoys the fused kernel, the backward is mathematically identical
to differentiating the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pallas_decode
from repro.kernels.flash_attention import flash_attention as _pallas_flash
from repro.kernels.rmsnorm import rms_norm as _pallas_rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _pallas_ssd
from repro.kernels.trie_plan import trie_plan_pallas as _pallas_trie_plan
from repro.kernels.xla_flash import decode_attention_xla, flash_attention_xla
from repro.kernels.xla_ssd import ssd_scan_chunked
from repro.kernels.xla_trie import fleet_plan_blocked

# below this many score elements the naive reference is cheaper than the
# blocked path (and small shapes may not tile evenly)
_NAIVE_ATTN_ELEMS = 512 * 512
_NAIVE_SSD_LEN = 256

_INTERPRET = True  # no TPU in this container; flipped by launch scripts


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention_pallas(q, k, v, causal, window):
    return _pallas_flash(q, k, v, causal=causal, window=window,
                         interpret=_INTERPRET)


def _attention_fwd(q, k, v, causal, window):
    return _attention_pallas(q, k, v, causal, window), (q, k, v)


def _attention_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_attention_pallas.defvjp(_attention_fwd, _attention_bwd)


def attention(q, k, v, *, causal=True, window=0, use_pallas=False):
    """(B,H,Sq,D) x (B,KV,Sk,D)^2 -> (B,H,Sq,D).

    XLA path dispatches to the blocked flash implementation for long
    sequences (O(S) memory, same math); the naive reference covers small
    shapes and serves as the oracle in tests."""
    if use_pallas:
        return _attention_pallas(q, k, v, causal, window)
    Sq, Sk = q.shape[2], k.shape[2]
    if (Sq * Sk > _NAIVE_ATTN_ELEMS and Sq % 512 == 0 and Sk % 512 == 0):
        return flash_attention_xla(q, k, v, causal, window)
    return ref.attention(q, k, v, causal=causal, window=window)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0,
                     use_pallas=False):
    """(B,H,D) x (B,KV,S,D)^2 -> (B,H,D). Inference-only (no vjp needed).

    Long caches use the blocked online-softmax path (no (B,H,S) score
    buffer); short caches use the naive oracle."""
    if use_pallas:
        return _pallas_decode(q, k_cache, v_cache, cache_len, window=window,
                              interpret=_INTERPRET)
    # NOTE: a blocked K-scan variant (decode_attention_xla) was tried and
    # REFUTED for the sharded dry-run: dynamic block slices over the
    # sequence-sharded cache force per-block all-gathers (435x collective
    # regression), while the naive einsum partitions into sequence-parallel
    # flash-decode under SPMD (EXPERIMENTS.md §Perf).  The Pallas kernel
    # covers the on-chip fusion on real TPUs.
    return ref.decode_attention(q, k_cache, v_cache, cache_len, window=window)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_pallas(x, dt, A, Bm, Cm, chunk):
    return _pallas_ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_INTERPRET)


def _ssd_fwd(x, dt, A, Bm, Cm, chunk):
    return _ssd_pallas(x, dt, A, Bm, Cm, chunk), (x, dt, A, Bm, Cm)


def _ssd_bwd(chunk, res, g):
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(lambda *a: ref.ssd_scan(*a, chunk=chunk), x, dt, A, Bm, Cm)
    return vjp(g)


_ssd_pallas.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=64, use_pallas=False,
             init_state=None, return_state=False):
    """Chunked SSD scan.  Pallas kernel for the stateless full-sequence
    form; XLA path uses the chunk-parallel formulation (associative scan
    over chunks — no sequential time-scan) for long sequences and the
    sequential oracle for short ones."""
    if use_pallas and init_state is None and not return_state:
        return _ssd_pallas(x, dt, A, Bm, Cm, chunk)
    S = x.shape[1]
    if S > _NAIVE_SSD_LEN and S % min(chunk, S) == 0:
        return ssd_scan_chunked(x, dt, A, Bm, Cm, chunk=chunk,
                                init_state=init_state,
                                return_state=return_state)
    return ref.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                        init_state=init_state, return_state=return_state)


def ssd_decode_step(x, dt, A, Bm, Cm, state):
    return ref.ssd_decode_step(x, dt, A, Bm, Cm, state)


def rms_norm(x, scale, eps=1e-6, *, use_pallas=False):
    if use_pallas:
        return _pallas_rmsnorm(x, scale, eps, interpret=_INTERPRET)
    return ref.rms_norm(x, scale, eps)


TRIE_PLAN_VARIANTS = ("dense", "fused", "pallas")


def trie_plan(terminal, depth, acc, cost, lat, subtree_size, path_models,
              path_counts, engine_of_model, prefixes, elapsed_lat,
              elapsed_cost, engine_delays, acc_floor, cost_cap, lat_cap,
              *, kind, variant="fused", use_pallas=False,
              blocked_depth=None):
    """Fused fleet replan -> (targets, next_models), both (B,) int32.

    The VineLM control-plane hot path (`controller_jax._fleet_step` routes
    here).  ``variant`` selects the implementation:

    - "pallas" (or ``use_pallas=True``) -> the tiled Pallas kernel
      (``interpret=True`` on CPU, compiled on TPU);
    - "fused"  -> the blocked XLA mirror (same tile math, jnp fori-loop) —
      the default serving path and the form CPU CI benchmarks;
    - "dense"  -> the pure-jnp reference (`ref.fleet_plan`): one full
      min-pass per lexicographic key with the (N, Dmax) delay intermediate
      materialized — the oracle tests compare against and the pre-fusion
      baseline `benchmarks/table3_overhead.py` measures.

    All three pick the identical node (exact float32 key comparisons, same
    tie-breaking as the host ``select_path``); inference-only, no vjp.

    ``blocked_depth`` (N,) float32 is the engine-availability mask as a
    node column (fault-tolerant serving): a candidate ``v`` is admissible
    from prefix ``u`` only when ``blocked_depth[v] <= depth[u]``.  ``None``
    (or all-zeros) means every engine is up — identical plans to the
    pre-fault contract.
    """
    if blocked_depth is None:
        blocked_depth = jnp.zeros_like(terminal)
    if use_pallas:
        variant = "pallas"
    if variant == "pallas":
        return _pallas_trie_plan(
            terminal, depth, acc, cost, lat, subtree_size, path_models,
            path_counts, engine_of_model, prefixes, elapsed_lat,
            elapsed_cost, engine_delays, acc_floor, cost_cap, lat_cap,
            kind=kind, blocked_depth=blocked_depth, interpret=_INTERPRET)
    if variant == "fused":
        return fleet_plan_blocked(
            terminal, depth, acc, cost, lat, subtree_size, path_models,
            path_counts, engine_of_model, prefixes, elapsed_lat,
            elapsed_cost, engine_delays, acc_floor, cost_cap, lat_cap,
            kind=kind, blocked_depth=blocked_depth)
    if variant != "dense":
        raise ValueError(
            f"unknown trie_plan variant {variant!r}: {TRIE_PLAN_VARIANTS}")
    return ref.fleet_plan(
        terminal, depth, acc, cost, lat, subtree_size, path_models,
        engine_of_model, prefixes, elapsed_lat, elapsed_cost,
        engine_delays, acc_floor, cost_cap, lat_cap, kind=kind,
        blocked_depth=blocked_depth)
