"""Fused RMSNorm as a Pallas kernel (row-blocked, feature dim resident).

Small but on the serving hot path: fusing the square-mean, rsqrt and scale
into one VMEM pass avoids two extra HBM round-trips per layer-norm site.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rms_norm(
    x: jnp.ndarray,      # (..., D)
    scale: jnp.ndarray,  # (D,)
    eps: float = 1e-6,
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_blocks = xf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
