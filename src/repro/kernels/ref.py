"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth (tests sweep shapes/dtypes and
assert_allclose kernels against them) AND the XLA fallback path used when
``use_pallas=False`` (e.g. the CPU dry-run; Pallas targets TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------
def attention(
    q: jnp.ndarray,   # (B, H, Sq, D)
    k: jnp.ndarray,   # (B, KV, Sk, D)
    v: jnp.ndarray,   # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,          # >0: sliding window (causal only)
    q_offset: int = 0,        # absolute position of q[0] (prefill chunking)
    scale: float | None = None,
) -> jnp.ndarray:
    """Grouped-query attention; returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KV, G, Sq, D)
    logits = jnp.einsum("bkgqd,bkTd->bkgqT", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqT,bkTd->bkgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, H, D) one new token per sequence
    k_cache: jnp.ndarray,  # (B, KV, S, D)
    v_cache: jnp.ndarray,  # (B, KV, S, D)
    cache_len: jnp.ndarray | int,  # () or (B,) valid prefix length
    *,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Single-token decode attention over a KV cache; returns (B, H, D)."""
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, KV, G, D)
    # bf16 operands with f32 accumulation: casting the cache to f32 would
    # double the dominant decode HBM traffic (§Perf, qwen2 decode)
    logits = jnp.einsum("bkgd,bkTd->bkgT", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    lens = jnp.asarray(cache_len)
    lens = jnp.broadcast_to(lens, (B,))
    pos = jnp.arange(S)[None, :]
    mask = pos < lens[:, None]
    if window > 0:
        mask &= pos >= (lens[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgT,bkTd->bkgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


# ----------------------------------------------------------------------
# Mamba2 / SSD (state-space duality) chunked scan
# ----------------------------------------------------------------------
def ssd_scan(
    x: jnp.ndarray,     # (B, S, Hn, P)   inputs per head
    dt: jnp.ndarray,    # (B, S, Hn)      softplus-activated step sizes
    A: jnp.ndarray,     # (Hn,)           negative decay rates (A < 0)
    Bm: jnp.ndarray,    # (B, S, N)       input projections (shared heads)
    Cm: jnp.ndarray,    # (B, S, N)       output projections (shared heads)
    *,
    chunk: int = 64,
    init_state: jnp.ndarray | None = None,  # (B, Hn, P, N)
    return_state: bool = False,
):
    """Sequential reference of the SSD recurrence:

        h_t = exp(A * dt_t) * h_{t-1} + dt_t * x_t  (outer) B_t
        y_t = h_t @ C_t

    This O(S) scan is the oracle; the Pallas kernel implements the chunked
    (quadratic-intra / recurrent-inter) algorithm from the Mamba2 paper.
    """
    Bq, S, Hn, P = x.shape
    N = Bm.shape[-1]
    h0 = (
        init_state
        if init_state is not None
        else jnp.zeros((Bq, Hn, P, N), jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,Hn,P), (B,Hn), (B,N), (B,N)
        decay = jnp.exp(A[None, :] * dtt)  # (B,Hn)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cm.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,Hn,P)
    if return_state:
        return y, h
    return y


def ssd_decode_step(
    x: jnp.ndarray,    # (B, Hn, P)
    dt: jnp.ndarray,   # (B, Hn)
    A: jnp.ndarray,    # (Hn,)
    Bm: jnp.ndarray,   # (B, N)
    Cm: jnp.ndarray,   # (B, N)
    state: jnp.ndarray,  # (B, Hn, P, N)
):
    """One-token SSD state update; returns (y, new_state)."""
    decay = jnp.exp(A[None, :] * dt.astype(jnp.float32))
    upd = jnp.einsum(
        "bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None], Bm.astype(jnp.float32)
    )
    new = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new


# ----------------------------------------------------------------------
# fused RMSNorm
# ----------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# trie fleet-replan (VineLM controller)
# ----------------------------------------------------------------------
_PLAN_BIG = 1e30


def _plan_lex_argmin(feas: jnp.ndarray, keys: tuple) -> jnp.ndarray:
    """Exact lexicographic argmin over the feasible set (multi-pass
    narrowing; final tie-break is the lowest node index, matching
    np.lexsort's stable order in the host ``select_path``)."""
    n = feas.shape[0]
    cand = feas
    for k in keys:
        kk = jnp.where(cand, k, _PLAN_BIG)
        cand = cand & (kk <= kk.min())
    idx = jnp.arange(n, dtype=jnp.int32)
    best = jnp.min(jnp.where(cand, idx, n)).astype(jnp.int32)
    return jnp.where(jnp.any(cand), best, jnp.int32(-1))


def fleet_plan(
    terminal: jnp.ndarray,         # (N,) float32 0/1
    depth: jnp.ndarray,            # (N,) float32
    acc: jnp.ndarray,              # (N,)
    cost: jnp.ndarray,             # (N,)
    lat: jnp.ndarray,              # (N,)
    subtree_size: jnp.ndarray,     # (N,) int32
    path_models: jnp.ndarray,      # (N, Dmax) int32, -1 padded
    engine_of_model: jnp.ndarray,  # (M,) int32
    prefixes: jnp.ndarray,         # (B,) int32 realized prefix nodes
    elapsed_lat: jnp.ndarray,      # (B,)
    elapsed_cost: jnp.ndarray,     # (B,)  (reporting only, see select_path)
    engine_delays: jnp.ndarray,    # (B, E) live per-engine delay vectors
    acc_floor: jnp.ndarray,        # ()  floor + margin (ignored for max_acc)
    cost_cap: jnp.ndarray,         # ()  (+_PLAN_BIG if absent)
    lat_cap: jnp.ndarray,          # ()  (+_PLAN_BIG if absent)
    *,
    kind: str,
    blocked_depth: jnp.ndarray | None = None,  # (N,) float32, 0 = clean
):
    """Dense masked-reduction oracle of the fused trie-replan kernel.

    One full min-pass per lexicographic key per request, with the (N, Dmax)
    cumulative-delay intermediate materialized — the pre-fusion form of the
    fleet step, kept as the correctness ground truth (`trie_plan.py` and
    `xla_trie.py` must pick the *identical* node) and as the "dense"
    dispatch variant benchmarked in `benchmarks/table3_overhead.py`.
    Returns (targets, next_models), both (B,) int32 with -1 = infeasible /
    stop here.

    ``blocked_depth[v]`` is the availability mask rendered as a node
    column: 1 + the deepest stage position on v's root path whose engine
    is currently down, 0 when the whole path is up.  A candidate is
    admissible from prefix ``u`` only when every *new* stage runs on a
    live engine — exactly ``blocked_depth[v] <= depth[u]`` (stages at or
    before the realized prefix already happened; checkpointed recovery
    keeps them).  All-zeros (every engine up) is a no-op mask.
    """
    n = acc.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    bd = (jnp.zeros_like(depth) if blocked_depth is None
          else blocked_depth.astype(depth.dtype))

    def select(u, el, ec, ed):
        per_model = ed[engine_of_model]                              # (M,)
        pm = path_models                                             # (N, D)
        vals = jnp.where(pm >= 0, per_model[jnp.maximum(pm, 0)], 0.0)
        delay = vals.sum(axis=1)
        lo = u
        hi = u + subtree_size[u]
        d_lat = (lat - lat[u]) + (delay - delay[u])
        d_cost = cost - cost[u]
        feas = (terminal > 0.5) & (idx >= lo) & (idx < hi)
        feas &= bd <= depth[u]
        feas &= d_lat <= (lat_cap - el) + 1e-6
        # cost budgets are expectation-based plan-level constraints (§3.3):
        # absolute C(v) <= cap, not re-conditioned on realized spend.  The
        # slack is *relative* — costs sit at ~1e-3 $ where an absolute 1e-6
        # would admit plans the float64 host search rejects.
        feas &= cost <= cost_cap + 1e-6 * jnp.abs(cost_cap)
        if kind == "min_cost":
            feas2 = feas & (acc >= acc_floor - 1e-6)
            keys = (d_cost, d_lat, depth)
            return _plan_lex_argmin(feas2, keys)
        keys = (-acc, d_cost, d_lat)
        return _plan_lex_argmin(feas, keys)

    tgt = jax.vmap(select)(prefixes, elapsed_lat, elapsed_cost, engine_delays)
    du = depth[prefixes].astype(jnp.int32)
    dmax = path_models.shape[1]
    nxt = path_models[jnp.maximum(tgt, 0), jnp.minimum(du, dmax - 1)]
    nxt = jnp.where((tgt < 0) | (tgt == prefixes), jnp.int32(-1), nxt)
    return tgt, nxt
