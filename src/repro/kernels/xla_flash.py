"""Memory-lean flash attention in pure XLA (lax.scan over blocks).

This is the XLA mirror of the Pallas kernel: identical math (online
softmax over K-blocks, O(S) residuals via custom_vjp recompute-backward),
expressed with lax.scan so the CPU dry-run lowers the same memory shape a
TPU kernel would have — the naive reference would otherwise materialize
the (B, H, Sq, Sk) logits (hundreds of GiB/device at 32k).

Forward residuals: (O, LSE) only.  Backward: standard flash backward —
D = rowsum(dO * O); per (q-block, k-block): recompute P, accumulate
dV += P^T dO, dS = P * (dO V^T - D), dQ += dS K, dK += dS^T Q.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(sq, sk, q0, k0, causal, window, dtype=jnp.float32):
    if not causal:
        return None
    qpos = q0 + jnp.arange(sq)[:, None]
    kpos = k0 + jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def _fwd_qblock(qb, k, v, q0, *, causal, window, scale, block_k):
    """qb: (B,KV,G,bq,D); k/v: (B,KV,Sk,D) -> (ob, lse_b)."""
    B, KV, G, bq, D = qb.shape
    Sk = k.shape[2]
    nk = Sk // block_k
    kb = k.reshape(B, KV, nk, block_k, D)
    vb = v.reshape(B, KV, nk, block_k, D)

    def inner(carry, ik):
        m_run, l_run, acc = carry
        kk = jnp.moveaxis(kb[:, :, ik], 2, 2)            # (B,KV,bk,D)
        vv = vb[:, :, ik]
        s = jnp.einsum("bkgqd,bktd->bkgqt", qb.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
        msk = _mask(bq, block_k, q0, ik * block_k, causal, window)
        if msk is not None:
            s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_cur = s.max(-1)
        m_new = jnp.maximum(m_run, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,bktd->bkgqd", p, vv.astype(jnp.float32))
        return (m_new, l_new, acc), ()

    init = (jnp.full((B, KV, G, bq), NEG_INF),
            jnp.zeros((B, KV, G, bq)),
            jnp.zeros((B, KV, G, bq, D)))
    (m, l, acc), _ = jax.lax.scan(inner, init, jnp.arange(nk))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    return acc / l_safe[..., None], m + jnp.log(l_safe)


def _flash_fwd_impl(q, k, v, causal, window, scale, block_q, block_k):
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    G = H // KV
    nq = Sq // block_q
    qb = q.reshape(B, KV, G, nq, block_q, D)

    def outer(_, iq):
        ob, lse = _fwd_qblock(
            qb[:, :, :, iq], k, v, iq * block_q,
            causal=causal, window=window, scale=scale, block_k=block_k)
        return (), (ob, lse)

    _, (O, LSE) = jax.lax.scan(outer, (), jnp.arange(nq))
    # O: (nq, B,KV,G,bq,D) -> (B,H,Sq,D)
    O = jnp.moveaxis(O, 0, 3).reshape(B, KV, G, Sq, D)
    LSE = jnp.moveaxis(LSE, 0, 3).reshape(B, KV, G, Sq)
    return O.reshape(B, H, Sq, D).astype(q.dtype), LSE


def _flash_bwd_impl(q, k, v, O, LSE, dO, causal, window, scale,
                    block_q, block_k):
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // block_q, Sk // block_k
    qb = q.reshape(B, KV, G, nq, block_q, Dh)
    dOb = dO.reshape(B, KV, G, nq, block_q, Dh)
    Ob = O.reshape(B, KV, G, nq, block_q, Dh)
    Lb = LSE.reshape(B, KV, G, nq, block_q)
    Db = jnp.sum(dOb.astype(jnp.float32) * Ob.astype(jnp.float32), -1)
    kb = k.reshape(B, KV, nk, block_k, Dh)
    vb = v.reshape(B, KV, nk, block_k, Dh)

    def outer(carry, iq):
        dK, dV = carry
        qq = qb[:, :, :, iq].astype(jnp.float32)
        do = dOb[:, :, :, iq].astype(jnp.float32)
        ll = Lb[:, :, :, iq]
        dd = Db[:, :, :, iq]

        def inner(inner_carry, ik):
            dK, dV, dq_acc = inner_carry
            kk = kb[:, :, ik].astype(jnp.float32)
            vv = vb[:, :, ik].astype(jnp.float32)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qq, kk) * scale
            msk = _mask(block_q, block_k, iq * block_q, ik * block_k,
                        causal, window)
            if msk is not None:
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - ll[..., None])
            dv_blk = jnp.einsum("bkgqt,bkgqd->bktd", p, do)
            dp = jnp.einsum("bkgqd,bktd->bkgqt", do, vv)
            ds = p * (dp - dd[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgqt,bktd->bkgqd", ds, kk)
            dk_blk = jnp.einsum("bkgqt,bkgqd->bktd", ds, qq)
            dK = jax.lax.dynamic_update_slice_in_dim(
                dK, jax.lax.dynamic_slice_in_dim(dK, ik * block_k,
                                                 block_k, 2) + dk_blk,
                ik * block_k, 2)
            dV = jax.lax.dynamic_update_slice_in_dim(
                dV, jax.lax.dynamic_slice_in_dim(dV, ik * block_k,
                                                 block_k, 2) + dv_blk,
                ik * block_k, 2)
            return (dK, dV, dq_acc), ()

        dq0 = jnp.zeros((B, KV, G, block_q, Dh))
        (dK, dV, dqb), _ = jax.lax.scan(inner, (dK, dV, dq0),
                                        jnp.arange(nk))
        return (dK, dV), dqb

    dK0 = jnp.zeros((B, KV, Sk, Dh))
    dV0 = jnp.zeros((B, KV, Sk, Dh))
    (dK, dV), dQ = jax.lax.scan(outer, (dK0, dV0), jnp.arange(nq))
    dQ = jnp.moveaxis(dQ, 0, 3).reshape(B, KV, G, Sq, Dh)
    return (dQ.reshape(B, H, Sq, Dh).astype(q.dtype),
            dK.astype(k.dtype), dV.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_xla(q, k, v, causal=True, window=0,
                        block_q=512, block_k=512):
    """(B,H,Sq,D) x (B,KV,Sk,D)^2 -> (B,H,Sq,D); O(S) memory."""
    bq = min(block_q, q.shape[2])
    bk = min(block_k, k.shape[2])
    scale = q.shape[-1] ** -0.5
    out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, bq, bk)
    return out


def _vjp_fwd(q, k, v, causal, window, block_q, block_k):
    bq = min(block_q, q.shape[2])
    bk = min(block_k, k.shape[2])
    scale = q.shape[-1] ** -0.5
    out, lse = _flash_fwd_impl(q, k, v, causal, window, scale, bq, bk)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, block_q, block_k, res, dO):
    q, k, v, out, lse = res
    bq = min(block_q, q.shape[2])
    bk = min(block_k, k.shape[2])
    scale = q.shape[-1] ** -0.5
    return _flash_bwd_impl(q, k, v, out, lse, dO, causal, window, scale,
                           bq, bk)


flash_attention_xla.defvjp(_vjp_fwd, _vjp_bwd)


def decode_attention_xla(q, k_cache, v_cache, cache_len, *, window=0,
                         block_k=2048):
    """Blocked single-token decode: online softmax over K-blocks — the XLA
    mirror of the flash-decode Pallas kernel.  Never materializes the
    (B, H, S) score tensor (the naive reference's dominant decode cost)."""
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    kb = k_cache.reshape(B, KV, nk, bk, D)
    vb = v_cache.reshape(B, KV, nk, bk, D)

    def body(carry, ik):
        m_run, l_run, acc = carry
        kk = kb[:, :, ik].astype(jnp.float32)           # (B,KV,bk,D)
        vv = vb[:, :, ik].astype(jnp.float32)
        s = jnp.einsum("bkgd,bktd->bkgt", qg, kk) * scale
        pos = ik * bk + jnp.arange(bk)[None, :]
        msk = pos < lens[:, None]
        if window > 0:
            msk &= pos >= (lens[:, None] - window)
        s = jnp.where(msk[:, None, None, :], s, NEG_INF)
        m_cur = s.max(-1)
        m_new = jnp.maximum(m_run, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgt,bktd->bkgd", p, vv)
        return (m_new, l_new, acc), ()

    init = (jnp.full((B, KV, G), NEG_INF), jnp.zeros((B, KV, G)),
            jnp.zeros((B, KV, G, D)))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).reshape(B, H, D).astype(q.dtype)
