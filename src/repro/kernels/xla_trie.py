"""XLA mirror of the fused trie-replan kernel (`trie_plan.py`).

Same blocked algorithm — per-request running lexicographic minima carried
across node tiles, cumulative engine delay as a path-counts matmul, the
first-step gather fused into the tournament — expressed as a jnp fori-loop
instead of a Pallas grid.  This is the path CPU CI benchmarks and the
default `use_pallas=False` dispatch run; it executes the *same*
`_tile_lexmin_update` helper as the kernel body, so the two cannot drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.trie_plan import (
    BIG,
    BIG_IDX,
    DEFAULT_BLOCK_NODES,
    _pad_to,
    _tile_lexmin_update,
    finalize,
    request_stats,
)


def fleet_plan_blocked(
    terminal, depth, acc, cost, lat, subtree_size, path_models,
    path_counts, engine_of_model, prefixes, elapsed_lat, elapsed_cost,
    engine_delays, acc_floor, cost_cap, lat_cap,
    *,
    kind: str,
    blocked_depth=None,
    block_nodes: int = DEFAULT_BLOCK_NODES,
):
    """Fused fleet replan: (targets, next_models), both (B,) int32.

    Same contract as `ref.fleet_plan` / `trie_plan.trie_plan_pallas`;
    ``blocked_depth`` (N,) is the engine-availability mask as a node
    column (see `_tile_lexmin_update`), ``None`` = every engine up.
    """
    del elapsed_cost
    if blocked_depth is None:
        blocked_depth = jnp.zeros_like(terminal)
    n = terminal.shape[0]
    bsz = prefixes.shape[0]
    # small tries fit one tile: skip the loop machinery entirely (the
    # running-minima pass degenerates to a single tile update)
    if n <= 4 * block_nodes:
        block_nodes = max((n + 7) // 8 * 8, 8)
    n_pad = -(-n // block_nodes) * block_nodes
    n_tiles = n_pad // block_nodes

    lo, hi, du, lat_u, cost_u, delay_u, thr, pmd, cap_eff, floor_eff = \
        request_stats(depth, cost, lat, subtree_size, path_counts,
                      engine_of_model, prefixes, elapsed_lat, engine_delays,
                      lat_cap, cost_cap, acc_floor)

    f32 = jnp.float32
    term_p = _pad_to(terminal.astype(f32), n_pad, 0.0)
    depth_p = _pad_to(depth.astype(f32), n_pad, 0.0)
    acc_p = _pad_to(acc.astype(f32), n_pad, 0.0)
    cost_p = _pad_to(cost.astype(f32), n_pad, 0.0)
    lat_p = _pad_to(lat.astype(f32), n_pad, 0.0)
    counts_p = _pad_to(path_counts.astype(f32), n_pad, 0.0)
    pm_p = _pad_to(path_models.astype(f32), n_pad, -1.0)
    bd_p = _pad_to(blocked_depth.astype(f32), n_pad, 0.0)

    carry0 = (
        jnp.full((bsz,), BIG, f32),
        jnp.full((bsz,), BIG, f32),
        jnp.full((bsz,), BIG, f32),
        jnp.full((bsz,), BIG_IDX, jnp.int32),
        jnp.full((bsz,), -1.0, f32),
    )

    def body(i, carry):
        s = i * block_nodes

        def tile(a):
            return jax.lax.dynamic_slice_in_dim(a, s, block_nodes)

        return _tile_lexmin_update(
            carry, s, tile(term_p), tile(depth_p), tile(acc_p),
            tile(cost_p), tile(lat_p), tile(counts_p), tile(pm_p),
            tile(bd_p), lo, hi, du, lat_u, cost_u, delay_u, thr, pmd,
            cap_eff, floor_eff, kind=kind)

    if n_tiles == 1:
        carry = body(0, carry0)
    else:
        carry = jax.lax.fori_loop(0, n_tiles, body, carry0)
    return finalize(carry, lo)
