"""Flash attention (fwd) as a Pallas TPU kernel.

TPU adaptation of the flash-attention algorithm: Q/K tiles sized for VMEM,
MXU-aligned (block sizes multiples of 128), online-softmax carried in VMEM
scratch across the sequential K-block grid axis.  GQA is handled by the
K/V BlockSpec index maps (query head h reads kv head h // group).

Validated against `ref.attention` in interpret mode (tests sweep shapes,
dtypes, causal/window) — this container has no TPU; `interpret=True`
executes the kernel body on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128  # TPU vreg lane count; m/l scratch replicated across lanes


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal, window, scale, block_q, block_k, n_kblocks,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (bq, d)
    k = k_ref[0].astype(jnp.float32)           # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bk)

    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                       # (bq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)             # (bq, 1)
    p = jnp.exp(s - m_new)                      # (bq, bk)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_kblocks - 1)
    def _done():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KV, Sk, D)
    v: jnp.ndarray,  # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = D ** -0.5

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * KV, Sk, D)
    vf = v.reshape(B * KV, Sk, D)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, n_kblocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki, g=G: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki, g=G: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
