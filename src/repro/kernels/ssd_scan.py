"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU adaptation of the Mamba2 chunked algorithm: the sequential recurrence
    h_t = exp(A dt_t) h_{t-1} + dt_t x_t (x) B_t ,   y_t = C_t . h_t
is reorganized into per-chunk *matmuls* (MXU-friendly) plus a tiny
inter-chunk state carry held in VMEM scratch:

  intra-chunk   M[t,s] = exp(L_t - L_s) dt_s (C_t . B_s)  (s <= t),
                y_intra = M @ x                        (Q x Q, Q x P matmuls)
  state read    y_state[t] = exp(L_t) * (C_t . h_in)
  state update  h_out = exp(L_Q) h_in + sum_s exp(L_Q - L_s) dt_s x_s (x) B_s

where L_t = cumsum(A dt) is the per-chunk log-decay.  A < 0 guarantees
exp(L_t - L_s) <= 1 for s <= t, so the log-space form is numerically safe.

Grid: (B, Hn, S/Q); the chunk axis is sequential ("arbitrary"), carrying
the (P, N) state in f32 scratch.  B/C projections are shared across heads
(their index maps ignore the head axis), matching Mamba2's ngroups=1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_scr,
    *, chunk, n_chunks,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0]                                  # () this head's A (< 0)
    bm = b_ref[0].astype(jnp.float32)             # (Q, N)
    cm = c_ref[0].astype(jnp.float32)             # (Q, N)

    logdec = jnp.cumsum(a * dt)                   # (Q,)  L_t
    # intra-chunk quadratic term
    cb = jax.lax.dot_general(                     # (Q, Q) = C @ B^T
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ratio = jnp.exp(logdec[:, None] - logdec[None, :])   # (Q, Q) L_t - L_s
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(spos <= tpos, ratio * cb * dt[None, :], 0.0)
    y = jax.lax.dot_general(                      # (Q, P)
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # contribution of the carried state: exp(L_t) * C_t @ h_in^T
    h = h_scr[...]                                # (P, N)
    y += jnp.exp(logdec)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)
    # state update: h_out = exp(L_Q) h_in + sum_s exp(L_Q - L_s) dt_s x_s B_s
    wts = jnp.exp(logdec[-1] - logdec) * dt       # (Q,)
    upd = jax.lax.dot_general(                    # (P, N) = x^T @ (wts*B)
        x, bm * wts[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h_scr[...] = jnp.exp(logdec[-1]) * h + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,   # (B, S, Hn, P)
    dt: jnp.ndarray,  # (B, S, Hn)
    A: jnp.ndarray,   # (Hn,)
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, Hn, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hn, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hn, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return out
