"""Pallas TPU kernels for the serving/training hot spots.

Each kernel ships three layers:
- ``<name>.py``  — pl.pallas_call + explicit BlockSpec VMEM tiling
  (flash_attention, decode_attention, ssd_scan, rmsnorm);
- ``ops.py``     — jit'd dispatch wrappers (use_pallas flag, custom_vjp
  recompute backwards, XLA fallbacks);
- ``ref.py``     — pure-jnp oracles used by the tests' allclose sweeps.

``xla_flash.py`` / ``xla_ssd.py`` are the XLA mirrors: same math expressed
with lax.scan / associative_scan so the CPU dry-run lowers the kernel's
memory shape (O(S) attention residuals, chunk-parallel SSD) without a TPU.
"""
