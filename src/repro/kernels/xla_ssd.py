"""Chunk-parallel SSD (Mamba2) in pure XLA.

Mirror of the Pallas chunked kernel without any sequential time-scan: the
inter-chunk recurrence h_{c+1} = A_c h_c + U_c is an *affine associative
scan* over chunks (log-depth), and all intra-chunk work is batched matmuls.
This keeps cost_analysis faithful (no under-counted scan bodies) and the
memory profile matches the kernel's (chunk-local quadratic only).

Per chunk (Q = chunk length, per head):
    L_t   = cumsum(A dt)                    (log decay within chunk)
    M     = tril(exp(L_t - L_s) * (C_t.B_s) * dt_s)
    y     = M x  +  exp(L_t) * (C_t . h_in(chunk))
    A_c   = exp(L_Q);  U_c = sum_s exp(L_Q - L_s) dt_s x_s (x) B_s
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_chunked(
    x: jnp.ndarray,   # (B, S, Hn, P)
    dt: jnp.ndarray,  # (B, S, Hn)
    A: jnp.ndarray,   # (Hn,)
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 256,
    init_state: jnp.ndarray | None = None,  # (B, Hn, P, N)
    return_state: bool = False,
):
    B, S, Hn, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(B, nc, Q, Hn, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, Hn)
    bf = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    cf = Cm.astype(jnp.float32).reshape(B, nc, Q, N)

    logdec = jnp.cumsum(A[None, None, None, :] * dtf, axis=2)  # (B,nc,Q,Hn)
    # intra-chunk quadratic term
    cb = jnp.einsum("bcqn,bcsn->bcqs", cf, bf)                 # (B,nc,Q,Q)
    ratio = jnp.exp(logdec[:, :, :, None, :] - logdec[:, :, None, :, :])
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tril[None, None, :, :, None],
                  ratio * cb[..., None] * dtf[:, :, None, :, :], 0.0)
    y = jnp.einsum("bcqsh,bcshp->bcqhp", M, xf)                # (B,nc,Q,Hn,P)

    # chunk-level affine recurrence elements
    a_c = jnp.exp(logdec[:, :, -1, :])                         # (B,nc,Hn)
    wts = jnp.exp(logdec[:, :, -1:, :] - logdec) * dtf         # (B,nc,Q,Hn)
    U = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", wts, xf, bf)      # (B,nc,Hn,P,N)

    # associative scan over chunks: (A2, U2) o (A1, U1) = (A2*A1, A2*U1+U2)
    def combine(l, r):
        al, ul = l
        ar, ur = r
        return ar * al, ar[..., None, None] * ul + ur

    a_cum, u_cum = jax.lax.associative_scan(combine, (a_c, U), axis=1)
    # h_in for chunk c = state after chunk c-1 (shift right); include h0
    h_after = u_cum                                            # zero-init part
    h_in = jnp.concatenate(
        [jnp.zeros_like(u_cum[:, :1]), u_cum[:, :-1]], axis=1)
    if init_state is not None:
        h0 = init_state.astype(jnp.float32)
        a_prefix = jnp.concatenate(
            [jnp.ones_like(a_cum[:, :1]), a_cum[:, :-1]], axis=1)
        h_in = h_in + a_prefix[..., None, None] * h0[:, None]
        h_after = h_after + a_cum[..., None, None] * h0[:, None]

    # state contribution: exp(L_t) * (C_t . h_in)
    y = y + jnp.exp(logdec)[..., None] * jnp.einsum(
        "bcqn,bchpn->bcqhp", cf, h_in)
    y = y.reshape(B, S, Hn, P).astype(x.dtype)
    if return_state:
        return y, h_after[:, -1]                               # (B,Hn,P,N)
    return y
