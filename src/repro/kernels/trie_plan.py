"""Fused trie-replan as a Pallas kernel (the VineLM control-plane hot path).

One fleet replan re-solves the re-rooted constrained search for every
in-flight request.  The dense form (`ref.fleet_plan`) materializes an
(N, Dmax) cumulative-delay intermediate per request and runs one full
min-pass per lexicographic key; this kernel fuses cumulative engine-delay,
feasibility masking, the exact multi-pass lexicographic argmin, and the
first-step gather into a single tiled pass:

- grid = (node tiles, batch lanes), node tiles OUTER: each trie SoA tile
  (terminal/depth/acc/cost/lat/path_counts/path_models) is fetched into
  VMEM once per node tile and stays resident while every batch-lane block
  streams past it;
- cumulative engine delay is a (TILE_N, M) x (M, TILE_B) matmul against the
  per-request per-model delay rows (path-multiplicity counts replace the
  (N, Dmax) gather+sum — MXU work instead of HBM traffic);
- each request carries per-key running minima (k1, k2, k3, node index,
  first-step model) in VMEM scratch across node tiles, merged
  lexicographically tile-by-tile — no full-array min-pass ever exists;
- the winner's first step is gathered from the *resident* path_models tile
  via one-hot contractions the moment the winner is found, so the fused
  pass emits (target, next_model) directly.

Tie-breaking is exact: every comparison is on identical float32 key values
(no epsilon-weighted composite keys), so the kernel picks the *same* node
as the dense oracle and the host ``select_path`` — the property the fleet
equivalence suites pin.  `xla_trie.fleet_plan_blocked` runs the identical
tile math (same `_tile_lexmin_update` helper) as a jnp fori-loop: the XLA
mirror for CPU CI, bitwise-aligned with interpret-mode Pallas.

One caveat on the dense oracle: the counts matmul groups the delay sum by
model (count x delta) where the oracle sums by path position, so the two
float32 `d_lat` values can in principle differ in the last ulp.  A
candidate sitting exactly one ulp from the feasibility threshold (which
already carries a 1e-6 slack vs the float64 host) or an exact key tie
could then split fused-vs-dense.  The contract actually enforced — and the
one serving relies on — is agreement with the host `select_path`, pinned
by the preset sweeps in tests/test_trie_plan.py and end-to-end by
tests/test_golden.py; a boundary flip fails those loudly rather than
drifting silently.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30        # infeasible key sentinel (matches ref._PLAN_BIG)
BIG_CUT = 1e29    # "no feasible node survived" detection threshold
BIG_IDX = 2 ** 30  # infeasible node-index sentinel

DEFAULT_BLOCK_NODES = 512
DEFAULT_BLOCK_LANES = 128


def request_stats(depth, cost, lat, subtree_size, path_counts,
                  engine_of_model, prefixes, elapsed_lat, engine_delays,
                  lat_cap, cost_cap, acc_floor):
    """Per-request prefix statistics + effective budgets (tiny gathers; runs
    as an XLA prologue shared by the Pallas kernel and the XLA mirror).

    Returns (lo, hi, du, lat_u, cost_u, delay_u, thr, pmd, cap_eff,
    floor_eff): interval bounds and prefix annotations per request, the
    remaining-latency threshold ``(lat_cap - elapsed) + 1e-6``, the (B, M)
    per-model delay rows, and the slack-adjusted cost/accuracy scalars —
    identical arithmetic to the dense oracle's feasibility masks.
    """
    u = prefixes
    lo = u.astype(jnp.int32)
    hi = (u + subtree_size[u]).astype(jnp.int32)
    du = depth[u].astype(jnp.int32)
    pmd = engine_delays[:, engine_of_model].astype(jnp.float32)   # (B, M)
    delay_u = jnp.sum(path_counts[u] * pmd, axis=-1)              # (B,)
    lat_u = lat[u]
    cost_u = cost[u]
    thr = (lat_cap - elapsed_lat) + 1e-6
    cap_eff = cost_cap + 1e-6 * jnp.abs(cost_cap)
    floor_eff = acc_floor - 1e-6
    return lo, hi, du, lat_u, cost_u, delay_u, thr, pmd, cap_eff, floor_eff


def _tile_lexmin_update(carry, idx0, term_t, depth_t, acc_t, cost_t, lat_t,
                        counts_t, pm_t, bd_t, lo, hi, du, lat_u, cost_u,
                        delay_u, thr, pmd, cap_eff, floor_eff, *, kind):
    """Merge one node tile into the per-request running lexicographic minima.

    ``carry`` = (bk1, bk2, bk3, bidx, bnxt), each (B,): the best key triple
    seen so far, its global node index, and the first-step model id gathered
    when that node became the incumbent.  Pure jnp — executed identically by
    the Pallas kernel body and the XLA mirror's fori-loop, so the two paths
    cannot drift.

    ``bd_t`` is the availability mask as a node column (``blocked_depth``:
    1 + deepest dead-engine stage position on the node's root path, 0 when
    clean); a candidate survives only if ``bd_t <= depth[u]`` — no *new*
    stage may sit on a down engine.  All-zeros means every engine is up.
    """
    bk1, bk2, bk3, bidx, bnxt = carry
    tile = term_t.shape[0]
    gidx = idx0 + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)  # (1, T)

    # cumulative engine delay for every (request, node) pair in the tile:
    # path-multiplicity counts x per-model delay rows — one MXU contraction
    delay_bt = jnp.dot(pmd, counts_t.T,
                       preferred_element_type=jnp.float32)         # (B, T)
    d_lat = (lat_t[None, :] - lat_u[:, None]) + (delay_bt - delay_u[:, None])
    d_cost = cost_t[None, :] - cost_u[:, None]
    feas = (term_t[None, :] > 0.5)
    feas &= (gidx >= lo[:, None]) & (gidx < hi[:, None])
    feas &= bd_t[None, :] <= du[:, None].astype(jnp.float32)
    feas &= d_lat <= thr[:, None]
    feas &= cost_t[None, :] <= cap_eff
    if kind == "min_cost":
        feas &= acc_t[None, :] >= floor_eff
        k1v, k2v, k3v = d_cost, d_lat, jnp.broadcast_to(depth_t[None, :],
                                                        d_lat.shape)
    else:
        k1v = jnp.broadcast_to(-acc_t[None, :], d_lat.shape)
        k2v, k3v = d_cost, d_lat

    # tile-local exact lexicographic argmin (narrowing over the tile only)
    k1 = jnp.where(feas, k1v, BIG)
    m1 = k1.min(axis=1)
    c2 = feas & (k1 <= m1[:, None])
    k2 = jnp.where(c2, k2v, BIG)
    m2 = k2.min(axis=1)
    c3 = c2 & (k2 <= m2[:, None])
    k3 = jnp.where(c3, k3v, BIG)
    m3 = k3.min(axis=1)
    c4 = c3 & (k3 <= m3[:, None])
    li = jnp.where(c4, gidx, BIG_IDX).min(axis=1).astype(jnp.int32)  # (B,)

    # first step of the tile winner, gathered from the RESIDENT pm tile:
    # pm_du[b, t] = pm_t[t, du_b] via a one-hot depth contraction, then the
    # winner row via a one-hot index mask — no dynamic gather needed.
    dmax = pm_t.shape[1]
    dio = jax.lax.broadcasted_iota(jnp.int32, (1, dmax), 1)          # (1, D)
    onehot_du = (dio == du[:, None]).astype(jnp.float32)             # (B, D)
    pm_du = jnp.dot(onehot_du, pm_t.T,
                    preferred_element_type=jnp.float32)              # (B, T)
    win = c4 & (gidx == li[:, None])
    nxt_t = jnp.sum(jnp.where(win, pm_du, 0.0), axis=1)              # (B,)

    # cross-tile lexicographic merge (strict: earlier tiles win exact ties,
    # preserving the lowest-node-index tie-break)
    better = (m1 < bk1) | (
        (m1 == bk1) & ((m2 < bk2) | (
            (m2 == bk2) & ((m3 < bk3) | (
                (m3 == bk3) & (li < bidx))))))
    return (
        jnp.where(better, m1, bk1),
        jnp.where(better, m2, bk2),
        jnp.where(better, m3, bk3),
        jnp.where(better, li, bidx),
        jnp.where(better, nxt_t, bnxt),
    )


def finalize(carry, lo):
    """(targets, next_models) from the final running minima."""
    bk1, _, _, bidx, bnxt = carry
    tgt = jnp.where(bk1 >= BIG_CUT, jnp.int32(-1), bidx.astype(jnp.int32))
    nxt = jnp.where((tgt < 0) | (tgt == lo), jnp.int32(-1),
                    bnxt.astype(jnp.int32))
    return tgt, nxt


def _trie_plan_kernel(scal_ref, term_ref, depth_ref, acc_ref, cost_ref,
                      lat_ref, counts_ref, pm_ref, bd_ref, lo_ref, hi_ref,
                      du_ref, latu_ref, costu_ref, delayu_ref, thr_ref,
                      pmd_ref, tgt_ref, nxt_ref,
                      bk1_ref, bk2_ref, bk3_ref, bidx_ref, bnxt_ref,
                      *, kind, block_nodes):
    n = pl.program_id(0)
    b = pl.program_id(1)
    tb = lo_ref.shape[0]
    sl = pl.ds(b * tb, tb)

    @pl.when(n == 0)
    def _():
        bk1_ref[sl] = jnp.full((tb,), BIG, jnp.float32)
        bk2_ref[sl] = jnp.full((tb,), BIG, jnp.float32)
        bk3_ref[sl] = jnp.full((tb,), BIG, jnp.float32)
        bidx_ref[sl] = jnp.full((tb,), BIG_IDX, jnp.int32)
        bnxt_ref[sl] = jnp.full((tb,), -1.0, jnp.float32)

    carry = (bk1_ref[sl], bk2_ref[sl], bk3_ref[sl], bidx_ref[sl],
             bnxt_ref[sl])
    carry = _tile_lexmin_update(
        carry, n * block_nodes,
        term_ref[...], depth_ref[...], acc_ref[...], cost_ref[...],
        lat_ref[...], counts_ref[...], pm_ref[...], bd_ref[...],
        lo_ref[...], hi_ref[...], du_ref[...], latu_ref[...],
        costu_ref[...], delayu_ref[...], thr_ref[...], pmd_ref[...],
        scal_ref[0], scal_ref[1], kind=kind)
    bk1_ref[sl], bk2_ref[sl], bk3_ref[sl], bidx_ref[sl], bnxt_ref[sl] = carry
    # running best is written every visit; the last node tile's write is the
    # final answer (output blocks are indexed by the batch lane only)
    tgt_ref[...], nxt_ref[...] = finalize(carry, lo_ref[...])


def _pad_to(x, size, fill):
    pad = size - x.shape[0]
    if pad == 0:
        return x
    widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def trie_plan_pallas(
    terminal, depth, acc, cost, lat, subtree_size, path_models,
    path_counts, engine_of_model, prefixes, elapsed_lat, elapsed_cost,
    engine_delays, acc_floor, cost_cap, lat_cap,
    *,
    kind: str,
    blocked_depth=None,
    block_nodes: int = DEFAULT_BLOCK_NODES,
    block_lanes: int = DEFAULT_BLOCK_LANES,
    interpret: bool = True,
):
    """Fused fleet replan: (targets, next_models), both (B,) int32.

    Same contract as `ref.fleet_plan`; `elapsed_cost` is accepted for
    signature parity (cost budgets are expectation-based, see select_path).
    ``blocked_depth`` (N,) is the engine-availability mask as a node
    column (see `_tile_lexmin_update`); ``None`` means every engine up.
    """
    del elapsed_cost
    if blocked_depth is None:
        blocked_depth = jnp.zeros_like(terminal)
    n = terminal.shape[0]
    bsz = prefixes.shape[0]
    block_nodes = min(block_nodes, max(pl.cdiv(n, 8) * 8, 8))
    n_pad = pl.cdiv(n, block_nodes) * block_nodes
    tb = min(block_lanes, max(pl.cdiv(bsz, 8) * 8, 8))
    b_pad = pl.cdiv(bsz, tb) * tb

    lo, hi, du, lat_u, cost_u, delay_u, thr, pmd, cap_eff, floor_eff = \
        request_stats(depth, cost, lat, subtree_size, path_counts,
                      engine_of_model, prefixes, elapsed_lat, engine_delays,
                      lat_cap, cost_cap, acc_floor)

    f32 = jnp.float32
    node_ops = [
        (_pad_to(terminal.astype(f32), n_pad, 0.0), (block_nodes,)),
        (_pad_to(depth.astype(f32), n_pad, 0.0), (block_nodes,)),
        (_pad_to(acc.astype(f32), n_pad, 0.0), (block_nodes,)),
        (_pad_to(cost.astype(f32), n_pad, 0.0), (block_nodes,)),
        (_pad_to(lat.astype(f32), n_pad, 0.0), (block_nodes,)),
        (_pad_to(path_counts.astype(f32), n_pad, 0.0),
         (block_nodes, path_counts.shape[1])),
        (_pad_to(path_models.astype(f32), n_pad, -1.0),
         (block_nodes, path_models.shape[1])),
        (_pad_to(blocked_depth.astype(f32), n_pad, 0.0), (block_nodes,)),
    ]
    # padded lanes get hi=0 (empty interval -> infeasible -> tgt -1)
    lane_ops = [
        (_pad_to(lo.astype(jnp.int32), b_pad, 0), jnp.int32),
        (_pad_to(hi.astype(jnp.int32), b_pad, 0), jnp.int32),
        (_pad_to(du, b_pad, 0), jnp.int32),
        (_pad_to(lat_u.astype(f32), b_pad, 0.0), f32),
        (_pad_to(cost_u.astype(f32), b_pad, 0.0), f32),
        (_pad_to(delay_u.astype(f32), b_pad, 0.0), f32),
        (_pad_to(thr.astype(f32), b_pad, 0.0), f32),
    ]
    pmd_p = _pad_to(pmd, b_pad, 0.0)
    scal = jnp.stack([jnp.asarray(cap_eff, f32), jnp.asarray(floor_eff, f32)])

    grid = (n_pad // block_nodes, b_pad // tb)
    in_specs = [pl.BlockSpec((2,), lambda i, j: (0,))]
    in_specs += [
        pl.BlockSpec(shape, lambda i, j, _nd=len(shape): (i,) + (0,) * (_nd - 1))
        for _, shape in node_ops
    ]
    in_specs += [pl.BlockSpec((tb,), lambda i, j: (j,))
                 for _ in lane_ops]
    in_specs += [pl.BlockSpec((tb, pmd_p.shape[1]), lambda i, j: (j, 0))]
    scratch = [pltpu.VMEM((b_pad,), f32), pltpu.VMEM((b_pad,), f32),
               pltpu.VMEM((b_pad,), f32), pltpu.VMEM((b_pad,), jnp.int32),
               pltpu.VMEM((b_pad,), f32)]

    tgt, nxt = pl.pallas_call(
        functools.partial(_trie_plan_kernel, kind=kind,
                          block_nodes=block_nodes),
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((tb,), lambda i, j: (j,)),
                   pl.BlockSpec((tb,), lambda i, j: (j,))),
        out_shape=(jax.ShapeDtypeStruct((b_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((b_pad,), jnp.int32)),
        scratch_shapes=scratch,
        interpret=interpret,
    )(scal, *[a for a, _ in node_ops], *[a for a, _ in lane_ops], pmd_p)
    return tgt[:bsz], nxt[:bsz]
