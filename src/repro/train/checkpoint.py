"""Fault-tolerant checkpoint manager (no orbax in this container).

Features required for 1000+-node runnability:
- **atomic saves**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a
  crash mid-save never corrupts the latest checkpoint;
- **async saves**: a background thread serializes a host snapshot while
  training continues (device->host copy happens synchronously, disk I/O
  does not);
- **mesh-agnostic restore**: arrays are saved logically (full shapes +
  manifest of the pytree); restore takes any target sharding, enabling
  *elastic* restarts on a different chip count / mesh;
- **integrity**: per-leaf checksums in the manifest, verified on restore;
- **preemption handling**: ``install_preemption_handler`` saves an
  emergency checkpoint on SIGTERM/SIGINT;
- retention of the newest ``keep`` checkpoints.

On a real multi-host pod each process writes only its addressable shards;
here (single process) arrays are saved whole.  The manifest format already
records per-leaf shape/dtype so the sharded writer is a drop-in extension.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable

import jax
import numpy as np

_SEP = "@"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _checksum(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> str:
        """Snapshot to host, then write (async if blocking=False)."""
        host = _flatten(tree)  # device->host copy happens here
        if blocking:
            return self._write(step, host)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict[str, np.ndarray]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = os.path.join(self.dir, f"tmp.{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host.items():
            fname = hashlib.md5(key.encode()).hexdigest()[:20] + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha": _checksum(arr),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``target``.  ``shardings`` (same
        pytree structure or a single sharding) enables elastic restore onto
        a different mesh than the checkpoint was written from."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_t, tdef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = None
        if shardings is not None and not hasattr(shardings, "device_set"):
            shard_flat = jax.tree.flatten(
                shardings, is_leaf=lambda x: hasattr(x, "device_set"))[0]
        leaves = []
        for i, (p, leaf) in enumerate(flat_t):
            key = _SEP.join(
                str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            if verify and _checksum(arr) != meta["sha"]:
                raise IOError(f"checksum mismatch for {key}")
            if shardings is None:
                leaves.append(arr)
            else:
                sh = shard_flat[i] if shard_flat is not None else shardings
                leaves.append(jax.device_put(arr, sh))
        return tdef.unflatten(leaves)


def install_preemption_handler(save_fn: Callable[[], None]):
    """SIGTERM/SIGINT -> emergency checkpoint, then exit.  Returns a flag
    dict the train loop can poll (``flag["preempted"]``)."""
    flag = {"preempted": False}

    def handler(signum, frame):
        flag["preempted"] = True
        save_fn()
        raise SystemExit(128 + signum)

    signal.signal(signal.SIGTERM, handler)
    signal.signal(signal.SIGINT, handler)
    return flag
