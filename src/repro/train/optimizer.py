"""Optimizers: AdamW (optionally int8-quantized moments) and Adafactor.

No optax in this container — implemented directly as (init, update) pairs
over parameter pytrees.  Notable features for the 480B-scale archs:

- ``quantize_moments``: stores Adam m/v as int8 with per-tensor-block
  scales (8x optimizer-memory reduction; beyond-paper memory lever);
- Adafactor: factored second moment (rank-1 row/col statistics) for
  matrices — O(n+m) state instead of O(nm);
- global-norm clipping, decoupled weight decay, cosine schedule w/ warmup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_moments: bool = False
    quant_block: int = 256


def cosine_lr(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.peak_lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ----------------------------------------------------------------------
# int8 block quantization for optimizer moments
# ----------------------------------------------------------------------
def _quant(x: jnp.ndarray, block: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


_LOG_FLOOR = 1e-24


def _quant_log(x: jnp.ndarray, block: int):
    """Log-domain int8 for non-negative second moments: linear absmax
    quantization under-resolves v's dynamic range inside a block (tiny v
    rounds to 0 -> exploding Adam denominators); ~0.2 log-units of
    resolution keeps relative error ~20% which Adam tolerates."""
    lg = jnp.log(jnp.maximum(x, _LOG_FLOOR))
    flat = lg.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    lo = flat.min(axis=1, keepdims=True)
    hi = flat.max(axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-6) / 254.0
    q = jnp.clip(jnp.round((flat - lo) / scale) - 127, -127, 127).astype(jnp.int8)
    return q, jnp.concatenate([lo, scale], axis=1).astype(jnp.float32)


def _dequant_log(q: jnp.ndarray, meta: jnp.ndarray, shape, block: int):
    lo, scale = meta[:, :1], meta[:, 1:2]
    lg = (q.astype(jnp.float32) + 127.0) * scale + lo
    flat = jnp.exp(lg).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    v = flat[:n].reshape(shape)
    return jnp.where(v <= 2 * _LOG_FLOOR, 0.0, v)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------
def adamw(cfg: OptConfig):
    def init(params):
        def zeros_m(p):
            if cfg.quantize_moments and p.size >= cfg.quant_block:
                q, s = _quant(jnp.zeros_like(p, jnp.float32), cfg.quant_block)
                return {"q": q, "s": s}
            return jnp.zeros_like(p, jnp.float32)

        def zeros_v(p):
            if cfg.quantize_moments and p.size >= cfg.quant_block:
                q, s = _quant_log(jnp.zeros_like(p, jnp.float32),
                                  cfg.quant_block)
                return {"q": q, "s": s}
            return jnp.zeros_like(p, jnp.float32)

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_m, params),
            "v": jax.tree.map(zeros_v, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = cosine_lr(cfg, step)
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

        def leaf(g, m_st, v_st, p):
            g = g.astype(jnp.float32) * scale
            quant = isinstance(m_st, dict)
            m = _dequant(m_st["q"], m_st["s"], g.shape, cfg.quant_block) \
                if quant else m_st
            v = _dequant_log(v_st["q"], v_st["s"], g.shape, cfg.quant_block) \
                if quant else v_st
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
            upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            if quant:
                mq, ms = _quant(m, cfg.quant_block)
                vq, vs = _quant_log(v, cfg.quant_block)
                return new_p, {"q": mq, "s": ms}, {"q": vq, "s": vs}
            return new_p, m, v

        is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
        flat_p = jax.tree.flatten(params)[0]
        out = [leaf(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}, \
            {"lr": lr, "grad_norm": gnorm}

    return init, update


# ----------------------------------------------------------------------
# Adafactor (factored second moment; for the 480B-class archs)
# ----------------------------------------------------------------------
def adafactor(cfg: OptConfig):
    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(st, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = cosine_lr(cfg, step)
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        decay = 1.0 - step.astype(jnp.float32) ** -0.8

        def leaf(g, v_st, p):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                vr = decay * v_st["vr"] + (1 - decay) * g2.mean(-1)
                vc = decay * v_st["vc"] + (1 - decay) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                       1e-30))
                upd = g / (jnp.sqrt(denom) + 1e-30)
                new_v = {"vr": vr, "vc": vc}
            else:
                v = decay * v_st["v"] + (1 - decay) * g2
                upd = g / (jnp.sqrt(v) + 1e-30)
                new_v = {"v": v}
            # update clipping (Adafactor's d=1.0 RMS rule)
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_v

        is_st = lambda x: isinstance(x, dict) and (
            set(x) == {"vr", "vc"} or set(x) == {"v"})
        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_st)[0]
        flat_p = jax.tree.flatten(params)[0]
        out = [leaf(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "v": new_v}, \
            {"lr": lr, "grad_norm": gnorm}

    return init, update


def make_optimizer(cfg: OptConfig):
    return adafactor(cfg) if cfg.kind == "adafactor" else adamw(cfg)
