"""Fault-tolerant training loop.

Wires together: data pipeline -> jitted train step (pjit sharded when a
mesh is supplied) -> metrics -> checkpoint manager.  Fault tolerance:
- restore-on-start from the latest checkpoint (params, opt state, data
  iterator position);
- periodic + async checkpoints;
- preemption handler (SIGTERM -> emergency save);
- step-time watchdog: steps slower than ``watchdog_factor`` x the running
  median are logged as straggler events (on a real fleet this feeds the
  controller's load model; here it exercises the code path).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager, install_preemption_handler
from repro.train.step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    async_ckpt: bool = True
    watchdog_factor: float = 3.0
    log_every: int = 10


def train(
    model,
    data,
    tcfg: TrainConfig,
    lcfg: LoopConfig,
    *,
    key=None,
    mesh=None,
    params=None,
    handle_preemption: bool = False,
    log: Callable[[str], None] = print,
) -> dict:
    key = key if key is not None else jax.random.PRNGKey(0)
    init_state, train_step = make_train_step(model, tcfg)
    if params is None:
        params = model.init(key)
    state = init_state(params)
    mgr = CheckpointManager(lcfg.ckpt_dir, keep=lcfg.keep)

    start_step = 0
    latest = mgr.latest_step()
    if latest is not None:
        tree = {"params": params, "state": state,
                "data": data.checkpoint_state()}
        restored = mgr.restore(latest, tree)
        params, state = restored["params"], restored["state"]
        data.restore_state(jax.tree.map(lambda x: x.item()
                                        if hasattr(x, "item") else x,
                                        restored["data"]))
        start_step = latest
        log(f"[restore] resumed from step {latest}")

    if mesh is not None:
        from repro.dist.sharding import sharding_tree
        p_shard = sharding_tree(params, mesh)
        params = jax.device_put(params, p_shard)
        step_fn = jax.jit(train_step)
    else:
        step_fn = jax.jit(train_step)

    def emergency_save():
        mgr.wait()
        mgr.save(lcfg.total_steps + 10**6,
                 {"params": params, "state": state,
                  "data": data.checkpoint_state()}, blocking=True)

    if handle_preemption:
        install_preemption_handler(emergency_save)

    times: list[float] = []
    straggler_events = 0
    losses = []
    for step in range(start_step, lcfg.total_steps):
        batch = data.next_batch()
        t0 = time.perf_counter()
        params, state, metrics = step_fn(params, state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if len(times) >= 5 and dt > lcfg.watchdog_factor * float(
                np.median(times)):
            straggler_events += 1
            log(f"[watchdog] step {step} took {dt:.3f}s "
                f"(median {np.median(times):.3f}s) — straggler event")
        times.append(dt)
        losses.append(float(metrics["loss"]))
        if (step + 1) % lcfg.log_every == 0:
            log(f"step {step+1}: loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if (step + 1) % lcfg.ckpt_every == 0 or step + 1 == lcfg.total_steps:
            mgr.save(step + 1,
                     {"params": params, "state": state,
                      "data": data.checkpoint_state()},
                     blocking=not lcfg.async_ckpt)
    mgr.wait()
    return {
        "params": params,
        "state": state,
        "losses": losses,
        "straggler_events": straggler_events,
        "manager": mgr,
    }
