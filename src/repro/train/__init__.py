"""Training substrate: optimizers, train step, fault-tolerant loop."""
from repro.train.checkpoint import CheckpointManager, install_preemption_handler
from repro.train.loop import LoopConfig, train
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train.step import TrainConfig, make_train_step

__all__ = ["CheckpointManager", "LoopConfig", "OptConfig", "TrainConfig",
           "install_preemption_handler", "make_optimizer", "make_train_step",
           "train"]
