"""Training step: value_and_grad + optimizer, with gradient accumulation
and int8 error-feedback gradient compression (optional).

``make_train_step`` returns a pure function suitable for jit/pjit —
the dry-run lowers exactly this function for every (arch x train shape).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum_steps: int = 1          # grad accumulation microbatches
    compress_grads: bool = False  # int8 error-feedback compression
    compress_block: int = 256


def _compress_ef(grads, err, block):
    """int8 error-feedback compression (numerical-fidelity model of on-wire
    gradient compression: quantize (g + e), carry the residual e forward)."""
    from repro.train.optimizer import _dequant, _quant

    def leaf(g, e):
        tot = g.astype(jnp.float32) + e
        if g.size < block:
            return tot, jnp.zeros_like(e)
        q, s = _quant(tot, block)
        deq = _dequant(q, s, g.shape, block)
        return deq, tot - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.flatten(err)[0]
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def make_train_step(model, tcfg: TrainConfig):
    opt_init, opt_update = make_optimizer(tcfg.opt)

    def init_state(params):
        st = {"opt": opt_init(params)}
        if tcfg.compress_grads:
            st["ef_err"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return st

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, state, batch):
        if tcfg.accum_steps > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), ()

            micro_batches = jax.tree.map(
                lambda x: x.reshape((tcfg.accum_steps,
                                     x.shape[0] // tcfg.accum_steps)
                                    + x.shape[1:]),
                batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zero, jnp.asarray(0.0, jnp.float32)), micro_batches)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, grads)
            loss = loss_sum / tcfg.accum_steps
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_state = dict(state)
        if tcfg.compress_grads:
            grads, new_err = _compress_ef(grads, state["ef_err"],
                                          tcfg.compress_block)
            new_state["ef_err"] = new_err
        new_params, new_opt, opt_metrics = opt_update(
            grads, state["opt"], params)
        new_state["opt"] = new_opt
        out_metrics = {"loss": loss, **opt_metrics}
        out_metrics.update({k: v for k, v in metrics.items()})
        return new_params, new_state, out_metrics

    return init_state, train_step
